//! The long-lived loopback decode server (ADR-004 §Serving).
//!
//! # Architecture
//!
//! One accept thread owns the `TcpListener`; each connection gets a
//! lightweight reader thread that *parses* frames but never computes:
//! it gathers every request already buffered on the socket into a
//! batch (bounded by `max_batch`) and submits the batch as ONE job to
//! the shared [`WorkerPool`] — the same bounded-queue substrate the
//! offline pipeline runs on, so compute parallelism and backpressure
//! are pool-wide properties rather than per-connection ones. The
//! fitted models live in a [`ModelCache`] behind `Arc`s: concurrent
//! clients share one resident model instead of deserializing one
//! copy each.
//!
//! # Shutdown
//!
//! [`ServerHandle::shutdown`] flips the shutdown flag, wakes the
//! accept loop with a loopback connect, joins the accept thread
//! (which joins every connection thread first) and only then drains
//! the worker pool via [`WorkerPool::finish`] — no stranded threads,
//! which the `serve_smoke` integration suite asserts.

use std::io::{BufReader, BufWriter, ErrorKind, Write};
use std::net::{Ipv4Addr, SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::cache::ModelCache;
use super::protocol::{
    read_opcode, read_request_body, write_response, Request, Response,
};
use crate::coordinator::WorkerPool;
use crate::error::{invalid, Result};
use crate::model::FittedModel;

/// Idle poll granularity: how often a blocked connection reader
/// rechecks the shutdown flag.
const IDLE_TICK: Duration = Duration::from_millis(200);

/// Patience for the body of a frame whose opcode already arrived.
const BODY_TIMEOUT: Duration = Duration::from_secs(10);

/// Server construction knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Path of the default `.fcm` model (loaded eagerly at start).
    pub model: PathBuf,
    /// TCP port on 127.0.0.1; `0` = ephemeral (see
    /// [`ServerHandle::addr`] for the bound address).
    pub port: u16,
    /// Worker threads; `0` = available parallelism.
    pub workers: usize,
    /// Resident-model budget of the LRU cache.
    pub cache_capacity: usize,
    /// Per-connection batch bound (requests per pool job).
    pub max_batch: usize,
    /// Optional event-log file (the CI smoke job uploads this).
    pub log_path: Option<PathBuf>,
}

impl ServeOptions {
    /// Defaults around a model path: ephemeral port, auto workers,
    /// 4-model cache, batches of up to 64 requests, no log.
    pub fn new(model: impl Into<PathBuf>) -> Self {
        ServeOptions {
            model: model.into(),
            port: 0,
            workers: 0,
            cache_capacity: 4,
            max_batch: 64,
            log_path: None,
        }
    }
}

/// Monotonic counters, snapshotted into [`ServeStats`].
#[derive(Default)]
struct Counters {
    connections: AtomicU64,
    requests: AtomicU64,
    batches: AtomicU64,
    errors: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> ServeStats {
        ServeStats {
            connections: self.connections.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time view of the server's traffic counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeStats {
    /// Client connections accepted.
    pub connections: u64,
    /// Requests answered (across all batches).
    pub requests: u64,
    /// Pool jobs executed (one per connection batch).
    pub batches: u64,
    /// Requests answered with a protocol-level error.
    pub errors: u64,
}

/// Timestamped, mutex-serialized event log (no-op without a path).
pub struct ServeLog {
    t0: Instant,
    file: Option<Mutex<BufWriter<std::fs::File>>>,
}

impl ServeLog {
    fn new(path: Option<&Path>) -> Result<Self> {
        let file = match path {
            None => None,
            Some(p) => {
                if let Some(parent) = p.parent() {
                    if !parent.as_os_str().is_empty() {
                        std::fs::create_dir_all(parent)?;
                    }
                }
                Some(Mutex::new(BufWriter::new(
                    std::fs::File::create(p)?,
                )))
            }
        };
        Ok(ServeLog { t0: Instant::now(), file })
    }

    /// Append one line (flushed immediately so crash logs survive).
    pub fn line(&self, msg: &str) {
        if let Some(f) = &self.file {
            let mut g = f.lock().expect("log poisoned");
            let t = self.t0.elapsed().as_secs_f64();
            let _ = writeln!(g, "[{t:9.3}s] {msg}");
            let _ = g.flush();
        }
    }
}

/// Everything the accept / connection / worker threads share.
struct ServerCtx {
    cache: ModelCache,
    default_model: PathBuf,
    model_dir: PathBuf,
    pool: Mutex<Option<WorkerPool>>,
    shutdown: AtomicBool,
    counters: Counters,
    log: ServeLog,
    max_batch: usize,
}

/// Entry point: [`Server::start`].
pub struct Server;

impl Server {
    /// Bind 127.0.0.1, eagerly load the default model (failing fast
    /// on a bad path), and spawn the accept loop. The returned handle
    /// owns the server's lifetime.
    pub fn start(opts: ServeOptions) -> Result<ServerHandle> {
        let workers = if opts.workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            opts.workers
        };
        let listener =
            TcpListener::bind((Ipv4Addr::LOCALHOST, opts.port))?;
        let addr = listener.local_addr()?;
        let model_dir = opts
            .model
            .parent()
            .filter(|p| !p.as_os_str().is_empty())
            .unwrap_or_else(|| Path::new("."))
            .to_path_buf();
        let ctx = Arc::new(ServerCtx {
            cache: ModelCache::new(opts.cache_capacity),
            default_model: opts.model.clone(),
            model_dir,
            pool: Mutex::new(Some(WorkerPool::new(
                workers,
                workers * 2,
            ))),
            shutdown: AtomicBool::new(false),
            counters: Counters::default(),
            log: ServeLog::new(opts.log_path.as_deref())?,
            max_batch: opts.max_batch.max(1),
        });
        let model = ctx.cache.get_or_load(&opts.model)?;
        ctx.log.line(&format!(
            "listening on {addr}: model {} (method {}, p={}, k={}), \
             {workers} workers",
            opts.model.display(),
            model.header.method.name(),
            model.header.p,
            model.header.k
        ));
        let actx = ctx.clone();
        let accept = std::thread::Builder::new()
            .name("serve-accept".into())
            .spawn(move || accept_loop(listener, actx))?;
        Ok(ServerHandle { addr, ctx, accept: Some(accept) })
    }
}

/// Owner of a running server: address, stats, and orderly teardown.
pub struct ServerHandle {
    addr: SocketAddr,
    ctx: Arc<ServerCtx>,
    accept: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound loopback address (resolves `port = 0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Current traffic counters.
    pub fn stats(&self) -> ServeStats {
        self.ctx.counters.snapshot()
    }

    /// Stop accepting, drain connections and workers, return the
    /// final counters. Joins every thread the server spawned.
    pub fn shutdown(mut self) -> Result<ServeStats> {
        self.stop_threads();
        Ok(self.ctx.counters.snapshot())
    }

    /// Block until the accept loop exits (a CLI `repro serve`
    /// foreground run — effectively forever unless the process is
    /// signalled), then drain the pool.
    pub fn wait(mut self) -> Result<ServeStats> {
        if let Some(h) = self.accept.take() {
            h.join()
                .map_err(|_| invalid("serve accept thread panicked"))?;
        }
        self.finish_pool();
        Ok(self.ctx.counters.snapshot())
    }

    fn stop_threads(&mut self) {
        self.ctx.shutdown.store(true, Ordering::Relaxed);
        // wake the blocking accept() so it observes the flag
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        self.finish_pool();
        self.ctx.log.line("shutdown complete");
    }

    fn finish_pool(&self) {
        let pool = self.ctx.pool.lock().expect("pool poisoned").take();
        if let Some(pool) = pool {
            let _: Vec<()> = pool.finish();
            self.ctx.log.line("worker pool drained");
        }
    }
}

impl Drop for ServerHandle {
    /// Dropping an un-shutdown handle still tears the server down —
    /// tests that panic mid-flight must not leave threads behind.
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.stop_threads();
        }
    }
}

fn accept_loop(listener: TcpListener, ctx: Arc<ServerCtx>) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    let mut conn_id = 0u64;
    for inc in listener.incoming() {
        if ctx.shutdown.load(Ordering::Relaxed) {
            break;
        }
        match inc {
            Ok(stream) => {
                // reap handles of connections that already finished
                // so a long-lived server holds O(concurrent), not
                // O(ever-accepted), join handles
                conns.retain(|h| !h.is_finished());
                conn_id += 1;
                ctx.counters.connections.fetch_add(1, Ordering::Relaxed);
                let cctx = ctx.clone();
                let id = conn_id;
                let spawned = std::thread::Builder::new()
                    .name(format!("serve-conn-{id}"))
                    .spawn(move || handle_conn(stream, cctx, id));
                match spawned {
                    Ok(h) => conns.push(h),
                    Err(e) => {
                        ctx.log.line(&format!(
                            "conn {id}: spawn failed: {e}"
                        ));
                    }
                }
            }
            Err(e) => {
                ctx.log.line(&format!("accept error: {e}"));
            }
        }
    }
    for h in conns {
        let _ = h.join();
    }
    ctx.log.line("accept loop exited");
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// Resolve a request's model name against the cache. Empty = the
/// default model; anything else must be a bare file name (no path
/// separators, no leading dot) inside the server's model directory.
fn resolve_model(
    ctx: &ServerCtx,
    name: &str,
) -> Result<Arc<FittedModel>> {
    if name.is_empty() {
        return ctx.cache.get_or_load(&ctx.default_model);
    }
    let legal = !name.starts_with('.')
        && name.chars().all(|c| {
            c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')
        });
    if !legal {
        return Err(invalid(format!("illegal model name '{name}'")));
    }
    ctx.cache.get_or_load(&ctx.model_dir.join(name))
}

/// Execute one connection batch on a pool worker.
fn serve_batch(ctx: &ServerCtx, batch: Vec<Request>) -> Vec<Response> {
    batch
        .into_iter()
        .map(|rq| {
            let out = match rq {
                Request::ModelInfo { model } => resolve_model(ctx, &model)
                    .map(|m| Response::Info(m.info_json().to_string())),
                Request::Compress { model, x } => {
                    resolve_model(ctx, &model).and_then(|m| {
                        m.compress(&x).map(Response::Compressed)
                    })
                }
                Request::Predict { model, x } => {
                    resolve_model(ctx, &model).and_then(|m| {
                        m.predict_proba(&x).map(Response::Probabilities)
                    })
                }
            };
            out.unwrap_or_else(|e| {
                ctx.counters.errors.fetch_add(1, Ordering::Relaxed);
                Response::Error(e.to_string())
            })
        })
        .collect()
}

fn handle_conn(stream: TcpStream, ctx: Arc<ServerCtx>, id: u64) {
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(IDLE_TICK)).is_err() {
        return;
    }
    let Ok(read_half) = stream.try_clone() else {
        ctx.log.line(&format!("conn {id}: clone failed"));
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);
    ctx.log.line(&format!("conn {id}: open"));
    loop {
        if ctx.shutdown.load(Ordering::Relaxed) {
            break;
        }
        // idle wait, interruptible every IDLE_TICK
        let op = match read_opcode(&mut reader) {
            Ok(None) => break, // clean EOF
            Ok(Some(op)) => op,
            Err(ref e) if is_timeout(e) => continue,
            Err(e) => {
                ctx.log.line(&format!("conn {id}: read error: {e}"));
                break;
            }
        };
        // a frame is in flight: allow its body generous time, and
        // greedily batch every further request already buffered
        let _ = reader.get_ref().set_read_timeout(Some(BODY_TIMEOUT));
        let mut batch = Vec::new();
        let mut framing_err: Option<String> = None;
        match read_request_body(&mut reader, op) {
            Ok(rq) => batch.push(rq),
            Err(e) => {
                ctx.counters.errors.fetch_add(1, Ordering::Relaxed);
                ctx.log
                    .line(&format!("conn {id}: malformed frame: {e}"));
                let rs =
                    Response::Error(format!("malformed request: {e}"));
                let _ = write_response(&mut writer, &rs);
                let _ = writer.flush();
                break;
            }
        }
        while batch.len() < ctx.max_batch && !reader.buffer().is_empty()
        {
            match read_opcode(&mut reader) {
                Ok(Some(op)) => {
                    match read_request_body(&mut reader, op) {
                        Ok(rq) => batch.push(rq),
                        Err(e) => {
                            ctx.log.line(&format!(
                                "conn {id}: malformed frame: {e}"
                            ));
                            framing_err = Some(format!(
                                "malformed request: {e}"
                            ));
                            break;
                        }
                    }
                }
                _ => {
                    framing_err =
                        Some("request framing lost".to_string());
                    break;
                }
            }
        }
        let _ = reader.get_ref().set_read_timeout(Some(IDLE_TICK));
        let n_req = batch.len() as u64;
        // One pool job per batch; responses come back over a channel
        // so this thread writes them in request order. submit() can
        // block on the pool's bounded job queue while the mutex is
        // held — that serializes *submission* across connections
        // under saturation, but the queue itself is the bottleneck
        // in that regime either way, and compute keeps draining it.
        let (tx, rx) = mpsc::channel();
        {
            let job_ctx = ctx.clone();
            let mut guard = ctx.pool.lock().expect("pool poisoned");
            let Some(pool) = guard.as_mut() else {
                break; // shutting down
            };
            // drop bookkeeping entries of already-completed jobs so
            // the results queue stays bounded over the server's life
            pool.discard_ready_results();
            pool.submit(move || {
                let _ = tx.send(serve_batch(&job_ctx, batch));
            });
        }
        let Ok(responses) = rx.recv() else {
            break;
        };
        ctx.counters.batches.fetch_add(1, Ordering::Relaxed);
        ctx.counters.requests.fetch_add(n_req, Ordering::Relaxed);
        let mut broken = false;
        for rs in &responses {
            if write_response(&mut writer, rs).is_err() {
                broken = true;
                break;
            }
        }
        if broken || writer.flush().is_err() {
            ctx.log.line(&format!("conn {id}: write failed"));
            break;
        }
        ctx.log
            .line(&format!("conn {id}: served batch of {n_req}"));
        if let Some(msg) = framing_err {
            // the stream is desynced past this batch: tell the
            // client why before closing, mirroring the first-frame
            // malformed path
            ctx.counters.errors.fetch_add(1, Ordering::Relaxed);
            let _ = write_response(&mut writer, &Response::Error(msg));
            let _ = writer.flush();
            break;
        }
    }
    ctx.log.line(&format!("conn {id}: closed"));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{
        DataConfig, EstimatorConfig, Method, ReduceConfig,
    };
    use crate::model::{fit_model, save_model, FitOptions};
    use crate::serve::ServeClient;
    use crate::volume::MorphometryGenerator;

    fn saved_model(tag: &str) -> (PathBuf, crate::model::FittedModel) {
        let dc = DataConfig {
            dims: [8, 9, 7],
            n_samples: 24,
            seed: 3,
            ..Default::default()
        };
        let (ds, y) =
            MorphometryGenerator::new(dc.dims).generate(dc.n_samples, 3);
        let reduce = ReduceConfig {
            method: Method::Fast,
            ratio: 10,
            ..Default::default()
        };
        let est = EstimatorConfig {
            cv_folds: 3,
            max_iter: 60,
            ..Default::default()
        };
        let model = fit_model(
            &ds,
            &y,
            &reduce,
            &est,
            &dc,
            &FitOptions::default(),
        )
        .unwrap();
        let dir = std::env::temp_dir().join("fastclust_serve_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{tag}.fcm"));
        save_model(&path, &model).unwrap();
        (path, model)
    }

    #[test]
    fn start_rejects_missing_model() {
        let opts = ServeOptions::new("/nonexistent/m.fcm");
        assert!(Server::start(opts).is_err());
    }

    #[test]
    fn single_client_info_and_predict() {
        let (path, model) = saved_model("single");
        let mut opts = ServeOptions::new(&path);
        opts.workers = 2;
        let handle = Server::start(opts).unwrap();
        let mut client = ServeClient::connect(handle.addr()).unwrap();
        let info = client.model_info().unwrap();
        assert_eq!(
            info.get("k").unwrap().as_usize().unwrap(),
            model.header.k
        );
        // one synthetic sample, compared against the offline path
        let x = crate::volume::FeatureMatrix::from_vec(
            1,
            model.header.p,
            (0..model.header.p).map(|i| (i % 7) as f32).collect(),
        )
        .unwrap();
        let want = model.predict_proba(&x).unwrap();
        let got = client.predict(&x).unwrap();
        assert_eq!(got, want, "served == offline, bit-identical");
        // dimension mismatch must come back as a protocol error
        let bad = crate::volume::FeatureMatrix::zeros(1, 3);
        assert!(client.predict(&bad).is_err());
        drop(client);
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.connections, 1);
        assert!(stats.requests >= 3);
        assert_eq!(stats.errors, 1);
    }

    #[test]
    fn illegal_model_names_rejected() {
        let (path, _) = saved_model("names");
        let mut opts = ServeOptions::new(&path);
        opts.workers = 1;
        let handle = Server::start(opts).unwrap();
        let mut client = ServeClient::connect(handle.addr()).unwrap();
        for bad in ["../evil.fcm", "a/b.fcm", ".hidden"] {
            assert!(
                client.model_info_named(bad).is_err(),
                "name '{bad}' must be rejected"
            );
        }
        drop(client);
        handle.shutdown().unwrap();
    }
}
