//! The event-driven decode server (ADR-007, superseding the
//! thread-per-connection design of ADR-004).
//!
//! # Architecture
//!
//! One `serve-loop` thread owns every socket through a readiness
//! [`Poller`] (epoll on Linux, poll(2) elsewhere): it accepts
//! nonblocking connections from the binary listener and the optional
//! HTTP gateway, runs a per-connection read/write state machine, and
//! parses frames — but never computes. Parsed requests flow into a
//! cross-connection [`Batcher`]: concurrent compress / predict
//! requests against the same model coalesce into ONE sample-major
//! kernel pass on the shared [`WorkerPool`], and the responses are
//! demuxed back per connection in request order. Workers hand
//! encoded bytes back over a channel and interrupt the loop's wait
//! with a [`WakePipe`] wake.
//!
//! # Load shedding
//!
//! Admission is bounded by `max_connections`. A connection over
//! budget is *explicitly* rejected — a [`Response::Shed`] frame on
//! the binary port, HTTP 429 on the gateway — and then closed. Never
//! a silent drop, so clients can distinguish overload from failure.
//!
//! # Shutdown
//!
//! [`ServerHandle::shutdown`] flips the shutdown flag and wakes the
//! loop; the loop flushes the batcher, drains in-flight jobs,
//! best-effort writes buffered responses, then drains the worker
//! pool via [`WorkerPool::finish`] — no stranded threads, which the
//! `serve_smoke` integration suite asserts.

use std::collections::{HashMap, VecDeque};
use std::io::{BufWriter, ErrorKind, Read, Write};
use std::net::{Ipv4Addr, SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batch::{Batch, Batcher, PendingReq, Verb, Wire};
use super::registry::ModelRegistry;
use super::event_loop::{
    sys_fd, Event, Fd, Interest, Poller, Token, WakePipe, Waker,
};
use super::http::{self, HttpRequest, Parse};
use super::metrics::Metrics;
use super::protocol::{
    self, decode_request_body, Request, Response, MAX_BODY_BYTES,
};
use crate::coordinator::WorkerPool;
use crate::error::{invalid, Result};
use crate::json::{self, Value};
use crate::model::MappedModel;
use crate::volume::FeatureMatrix;

/// Idle wait bound: how long a quiet loop sleeps before rechecking
/// the shutdown flag (wakes interrupt it sooner).
const IDLE_TICK_MS: i32 = 200;

/// Bytes per `read(2)` into a connection buffer.
const READ_CHUNK: usize = 16 * 1024;

/// Reads per readable event before yielding to other connections
/// (level-triggered readiness re-reports leftover input).
const MAX_READS_PER_EVENT: usize = 16;

const TOK_BINARY: Token = 0;
const TOK_HTTP: Token = 1;
const TOK_WAKE: Token = 2;
const FIRST_CONN_TOKEN: Token = 3;

/// Server construction knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Path of the default `.fcm` model (loaded eagerly at start).
    pub model: PathBuf,
    /// TCP port on 127.0.0.1; `0` = ephemeral (see
    /// [`ServerHandle::addr`] for the bound address).
    pub port: u16,
    /// HTTP gateway port on 127.0.0.1: `None` = no gateway,
    /// `Some(0)` = ephemeral ([`ServerHandle::http_addr`]).
    pub http_port: Option<u16>,
    /// Worker threads; `0` = available parallelism.
    pub workers: usize,
    /// Resident-byte budget of the model registry (ADR-008): LRU
    /// models are evicted once the *measured* resident total — lazy
    /// mapped models cost O(touched sections), not file size —
    /// exceeds it.
    pub max_model_bytes: u64,
    /// Batch size cap (requests per pool job).
    pub max_batch: usize,
    /// Connection budget across both listeners; accepts past it are
    /// explicitly shed.
    pub max_connections: usize,
    /// Micro-batching flush window in microseconds: how long the
    /// head of a batch may wait for company under continuous load.
    pub batch_window_us: u64,
    /// Per-connection idle deadline in milliseconds (ADR-010);
    /// `0` disables it. A connection with no read/write progress and
    /// no in-flight work for this long is closed — so a slow-loris
    /// peer (bytes trickled slower than the deadline, request never
    /// completed) cannot pin a slot of the connection budget.
    pub idle_timeout_ms: u64,
    /// Optional event-log file (the CI smoke job uploads this).
    pub log_path: Option<PathBuf>,
}

impl ServeOptions {
    /// Defaults around a model path: ephemeral binary port, no HTTP
    /// gateway, auto workers, a 1 GiB registry byte budget, batches
    /// of up to 64 requests, 256-connection budget, 200 µs flush
    /// window, no log.
    pub fn new(model: impl Into<PathBuf>) -> Self {
        ServeOptions {
            model: model.into(),
            port: 0,
            http_port: None,
            workers: 0,
            max_model_bytes: 1 << 30,
            max_batch: 64,
            max_connections: 256,
            batch_window_us: 200,
            idle_timeout_ms: 0,
            log_path: None,
        }
    }
}

/// Monotonic counters, snapshotted into [`ServeStats`].
#[derive(Default)]
struct Counters {
    connections: AtomicU64,
    requests: AtomicU64,
    batches: AtomicU64,
    errors: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> ServeStats {
        ServeStats {
            connections: self.connections.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time view of the server's traffic counters. The richer
/// per-model / histogram view lives in
/// [`ServerHandle::metrics_json`] (the `GET /metrics` body).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeStats {
    /// Client connections accepted (admitted + shed, both wires).
    pub connections: u64,
    /// Model requests answered (across all batches, both wires).
    pub requests: u64,
    /// Pool jobs executed (one per coalesced batch).
    pub batches: u64,
    /// Requests answered with an error response.
    pub errors: u64,
}

/// Timestamped, mutex-serialized event log (no-op without a path).
pub struct ServeLog {
    t0: Instant,
    file: Option<Mutex<BufWriter<std::fs::File>>>,
}

impl ServeLog {
    fn new(path: Option<&Path>) -> Result<Self> {
        let file = match path {
            None => None,
            Some(p) => {
                if let Some(parent) = p.parent() {
                    if !parent.as_os_str().is_empty() {
                        std::fs::create_dir_all(parent)?;
                    }
                }
                Some(Mutex::new(BufWriter::new(
                    std::fs::File::create(p)?,
                )))
            }
        };
        Ok(ServeLog { t0: Instant::now(), file })
    }

    /// Append one line (flushed immediately so crash logs survive).
    pub fn line(&self, msg: &str) {
        if let Some(f) = &self.file {
            let mut g = f.lock().expect("log poisoned");
            let t = self.t0.elapsed().as_secs_f64();
            let _ = writeln!(g, "[{t:9.3}s] {msg}");
            let _ = g.flush();
        }
    }
}

/// Everything the loop and the worker jobs share.
struct ServerCtx {
    registry: ModelRegistry,
    default_model: PathBuf,
    model_dir: PathBuf,
    shutdown: AtomicBool,
    counters: Counters,
    metrics: Metrics,
    log: ServeLog,
}

/// Entry point: [`Server::start`].
pub struct Server;

impl Server {
    /// Bind 127.0.0.1, eagerly load the default model (failing fast
    /// on a bad path), and spawn the event loop. The returned handle
    /// owns the server's lifetime.
    pub fn start(opts: ServeOptions) -> Result<ServerHandle> {
        let workers = if opts.workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            opts.workers
        };
        let listener =
            TcpListener::bind((Ipv4Addr::LOCALHOST, opts.port))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let http_listener = match opts.http_port {
            None => None,
            Some(p) => {
                let l =
                    TcpListener::bind((Ipv4Addr::LOCALHOST, p))?;
                l.set_nonblocking(true)?;
                Some(l)
            }
        };
        let http_addr = match &http_listener {
            None => None,
            Some(l) => Some(l.local_addr()?),
        };
        let model_dir = opts
            .model
            .parent()
            .filter(|p| !p.as_os_str().is_empty())
            .unwrap_or_else(|| Path::new("."))
            .to_path_buf();
        let ctx = Arc::new(ServerCtx {
            registry: ModelRegistry::new(opts.max_model_bytes),
            default_model: opts.model.clone(),
            model_dir,
            shutdown: AtomicBool::new(false),
            counters: Counters::default(),
            metrics: Metrics::new(),
            log: ServeLog::new(opts.log_path.as_deref())?,
        });
        // mapping the default model fails fast on a bad path while
        // costing only O(header) bytes until traffic touches it
        let model = ctx.registry.get_or_load(&opts.model)?;
        let mut poller = Poller::new()?;
        let wake = WakePipe::new()?;
        poller.add(sys_fd(&listener), TOK_BINARY, Interest::READ)?;
        if let Some(l) = &http_listener {
            poller.add(sys_fd(l), TOK_HTTP, Interest::READ)?;
        }
        if wake.fd() >= 0 {
            poller.add(wake.fd(), TOK_WAKE, Interest::READ)?;
        }
        ctx.log.line(&format!(
            "listening on {addr}: model {} (method {}, p={}, k={}), \
             {workers} workers",
            opts.model.display(),
            model.header().method.name(),
            model.header().p,
            model.header().k
        ));
        ctx.log.line(&format!(
            "serve backend {}: {} connection budget, {} µs batch \
             window, batches of up to {}",
            poller.backend_name(),
            opts.max_connections,
            opts.batch_window_us,
            opts.max_batch.max(1)
        ));
        if let Some(ha) = http_addr {
            ctx.log.line(&format!("http gateway on {ha}"));
        }
        if opts.idle_timeout_ms > 0 {
            ctx.log.line(&format!(
                "idle deadline: {} ms per connection",
                opts.idle_timeout_ms
            ));
        }
        let waker = wake.waker();
        let (tx, rx) = mpsc::channel();
        let max_inflight = (workers * 2).max(2);
        let el = EventLoop {
            ctx: ctx.clone(),
            poller,
            binary: listener,
            http_listener,
            wake,
            tx,
            rx,
            pool: WorkerPool::new(workers, workers * 2),
            conns: HashMap::new(),
            batcher: Batcher::new(
                opts.batch_window_us,
                opts.max_batch,
            ),
            next_token: FIRST_CONN_TOKEN,
            inflight: 0,
            max_inflight,
            overflow: VecDeque::new(),
            max_connections: opts.max_connections.max(1),
            idle_timeout: (opts.idle_timeout_ms > 0)
                .then(|| Duration::from_millis(opts.idle_timeout_ms)),
        };
        let thread = std::thread::Builder::new()
            .name("serve-loop".into())
            .spawn(move || el.run())?;
        Ok(ServerHandle {
            addr,
            http_addr,
            ctx,
            waker,
            thread: Some(thread),
        })
    }
}

/// Owner of a running server: addresses, stats, orderly teardown.
pub struct ServerHandle {
    addr: SocketAddr,
    http_addr: Option<SocketAddr>,
    ctx: Arc<ServerCtx>,
    waker: Waker,
    thread: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound binary-protocol address (resolves `port = 0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The bound HTTP gateway address, when one was requested.
    pub fn http_addr(&self) -> Option<SocketAddr> {
        self.http_addr
    }

    /// Current traffic counters.
    pub fn stats(&self) -> ServeStats {
        self.ctx.counters.snapshot()
    }

    /// The full observability snapshot — exactly the JSON that
    /// `GET /metrics` serves.
    pub fn metrics_json(&self) -> Value {
        self.ctx.metrics.to_json(
            self.ctx.registry.loads(),
            self.ctx.registry.hits(),
            self.ctx.registry.stats_json(),
        )
    }

    /// Stop accepting, drain batches and workers, return the final
    /// counters. Joins every thread the server spawned.
    pub fn shutdown(mut self) -> Result<ServeStats> {
        self.stop_threads();
        Ok(self.ctx.counters.snapshot())
    }

    /// Block until the event loop exits (a CLI `repro serve`
    /// foreground run — effectively forever unless the process is
    /// signalled).
    pub fn wait(mut self) -> Result<ServeStats> {
        if let Some(h) = self.thread.take() {
            h.join()
                .map_err(|_| invalid("serve loop thread panicked"))?;
        }
        Ok(self.ctx.counters.snapshot())
    }

    /// Route SIGTERM to a graceful drain (ADR-010): the handler
    /// flips [`sigterm_requested`] and pokes the loop's wake pipe;
    /// the loop stops accepting, drains in-flight work under the
    /// usual 5 s deadline, and exits — so a foreground
    /// `repro serve` terminates with status 0 on SIGTERM instead of
    /// dying mid-write. No-op off unix.
    #[cfg(unix)]
    pub fn install_sigterm(&self) {
        const SIGTERM: i32 = 15;
        extern "C" {
            fn signal(
                signum: i32,
                handler: extern "C" fn(i32),
            ) -> usize;
        }
        SIGTERM_WAKE_FD
            .store(self.waker.raw_fd(), Ordering::Relaxed);
        unsafe {
            let _ = signal(SIGTERM, on_sigterm);
        }
    }

    /// No signals to install on this host.
    #[cfg(not(unix))]
    pub fn install_sigterm(&self) {}

    fn stop_threads(&mut self) {
        self.ctx.shutdown.store(true, Ordering::Relaxed);
        self.waker.wake();
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
        self.ctx.log.line("shutdown complete");
    }
}

impl Drop for ServerHandle {
    /// Dropping an un-shutdown handle still tears the server down —
    /// tests that panic mid-flight must not leave threads behind.
    fn drop(&mut self) {
        if self.thread.is_some() {
            self.stop_threads();
        }
    }
}

// ---------------------------------------------------- SIGTERM drain

/// Set by the SIGTERM handler; the event loop polls it every tick
/// and `/readyz` reports 503 once it flips.
static SIGTERM_FLAG: AtomicBool = AtomicBool::new(false);

/// Wake-pipe write fd the handler pokes so a blocked poller wait
/// notices the flag immediately (−1 until a handler is installed).
#[cfg(unix)]
static SIGTERM_WAKE_FD: std::sync::atomic::AtomicI32 =
    std::sync::atomic::AtomicI32::new(-1);

/// Whether a SIGTERM drain has been requested in this process.
pub fn sigterm_requested() -> bool {
    SIGTERM_FLAG.load(Ordering::Relaxed)
}

/// The handler body is async-signal-safe by construction: one atomic
/// store and one `write(2)` — no allocation, no locks, no stdio.
#[cfg(unix)]
extern "C" fn on_sigterm(_sig: i32) {
    SIGTERM_FLAG.store(true, Ordering::Relaxed);
    let fd = SIGTERM_WAKE_FD.load(Ordering::Relaxed);
    if fd >= 0 {
        extern "C" {
            fn write(
                fd: i32,
                buf: *const std::os::raw::c_void,
                count: usize,
            ) -> isize;
        }
        let byte = [1u8];
        unsafe {
            let _ = write(
                fd,
                byte.as_ptr() as *const std::os::raw::c_void,
                1,
            );
        }
    }
}

/// Resolve a request's model name against the registry. Empty = the
/// default model; anything else must be a bare file name (no path
/// separators, no leading dot) inside the server's model directory.
/// The registry re-stamps the file on every resolve, so a
/// rename-replaced model hot-reloads here while in-flight batches
/// finish on the `Arc` they already hold.
fn resolve_model(
    ctx: &ServerCtx,
    name: &str,
) -> Result<Arc<MappedModel>> {
    if name.is_empty() {
        return ctx.registry.get_or_load(&ctx.default_model);
    }
    let legal = !name.starts_with('.')
        && name.chars().all(|c| {
            c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')
        });
    if !legal {
        return Err(invalid(format!("illegal model name '{name}'")));
    }
    ctx.registry.get_or_load(&ctx.model_dir.join(name))
}

// --------------------------------------------------------- event loop

/// One response slot of a connection. Slots are created in request
/// order and flushed strictly in order — a later response waits in
/// its slot until every earlier one is on the write buffer.
struct Slot {
    data: Option<Vec<u8>>,
    close_after: bool,
}

/// Per-connection state machine.
struct Conn {
    stream: TcpStream,
    fd: Fd,
    http: bool,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    wpos: usize,
    base_slot: u64,
    next_slot: u64,
    slots: VecDeque<Slot>,
    read_shut: bool,
    dead: bool,
    interest: Interest,
    /// Last moment this connection made read or write progress;
    /// the idle reaper (ADR-010) measures against it.
    last_activity: Instant,
}

impl Conn {
    /// Pull readable bytes into `rbuf` (bounded per event;
    /// level-triggered readiness re-reports the rest).
    fn fill_rbuf(&mut self) {
        let mut chunk = [0u8; READ_CHUNK];
        let mut reads = 0;
        while reads < MAX_READS_PER_EVENT {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.read_shut = true;
                    return;
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&chunk[..n]);
                    self.last_activity = Instant::now();
                    reads += 1;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    return
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
    }

    /// Move completed head-of-line slots onto the write buffer.
    fn pump(&mut self) {
        while matches!(
            self.slots.front(),
            Some(s) if s.data.is_some()
        ) {
            let s = self.slots.pop_front().expect("front exists");
            self.base_slot += 1;
            self.wbuf
                .extend_from_slice(&s.data.expect("front complete"));
            if s.close_after {
                self.read_shut = true;
            }
        }
    }

    /// Write as much buffered output as the socket takes.
    fn write_pending(&mut self) {
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.dead = true;
                    return;
                }
                Ok(n) => {
                    self.wpos += n;
                    self.last_activity = Instant::now();
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        }
    }
}

/// Encoded responses of one executed batch: `(conn, slot, bytes)`.
type Completion = Vec<(Token, u64, Vec<u8>)>;

struct EventLoop {
    ctx: Arc<ServerCtx>,
    poller: Poller,
    binary: TcpListener,
    http_listener: Option<TcpListener>,
    wake: WakePipe,
    tx: mpsc::Sender<Completion>,
    rx: mpsc::Receiver<Completion>,
    pool: WorkerPool,
    conns: HashMap<Token, Conn>,
    batcher: Batcher,
    next_token: Token,
    inflight: usize,
    max_inflight: usize,
    overflow: VecDeque<Batch>,
    max_connections: usize,
    idle_timeout: Option<Duration>,
}

impl EventLoop {
    fn run(mut self) {
        let mut events: Vec<Event> = Vec::new();
        loop {
            if sigterm_requested()
                && !self.ctx.shutdown.load(Ordering::Relaxed)
            {
                self.ctx.log.line(
                    "SIGTERM: stop accepting, draining in-flight \
                     work",
                );
                self.ctx.shutdown.store(true, Ordering::Relaxed);
            }
            if self.ctx.shutdown.load(Ordering::Relaxed) {
                break;
            }
            // With requests waiting in the batcher, poll without
            // sleeping: a wait that comes back empty means nothing
            // else is arriving, so flush everything immediately
            // (quiescence) instead of sitting out the window.
            let timeout = if self.batcher.is_empty() {
                IDLE_TICK_MS
            } else {
                0
            };
            if let Err(e) = self.poller.wait(&mut events, timeout) {
                self.ctx.log.line(&format!("poller failed: {e}"));
                break;
            }
            let quiet = events.is_empty();
            for i in 0..events.len() {
                let ev = events[i];
                self.handle_event(ev);
            }
            self.drain_completions();
            let due = self.batcher.due(Instant::now());
            for b in due {
                self.dispatch(b);
            }
            if quiet && !self.batcher.is_empty() {
                let rest = self.batcher.drain();
                for b in rest {
                    self.dispatch(b);
                }
            }
            self.flush_and_sweep();
        }
        self.drain_and_exit();
    }

    fn handle_event(&mut self, ev: Event) {
        match ev.token {
            TOK_BINARY => {
                if ev.readable {
                    self.accept_all(false);
                }
            }
            TOK_HTTP => {
                if ev.readable {
                    self.accept_all(true);
                }
            }
            TOK_WAKE => self.wake.drain(),
            token => {
                if ev.readable || ev.hangup {
                    self.read_and_parse(token, ev.hangup);
                }
                if ev.writable {
                    if let Some(c) = self.conns.get_mut(&token) {
                        c.write_pending();
                    }
                }
            }
        }
    }

    // ------------------------------------------------------ admission

    fn accept_all(&mut self, http: bool) {
        loop {
            let res = if http {
                match &self.http_listener {
                    Some(l) => l.accept(),
                    None => return,
                }
            } else {
                self.binary.accept()
            };
            match res {
                Ok((stream, _)) => self.admit(stream, http),
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    return
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    self.ctx
                        .log
                        .line(&format!("accept error: {e}"));
                    return;
                }
            }
        }
    }

    fn admit(&mut self, stream: TcpStream, http: bool) {
        self.ctx
            .counters
            .connections
            .fetch_add(1, Ordering::Relaxed);
        self.ctx.metrics.accepted.fetch_add(1, Ordering::Relaxed);
        if self.conns.len() >= self.max_connections {
            self.shed(stream, http);
            return;
        }
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let _ = stream.set_nodelay(true);
        let token = self.next_token;
        self.next_token += 1;
        let fd = sys_fd(&stream);
        if let Err(e) = self.poller.add(fd, token, Interest::READ) {
            self.ctx.log.line(&format!(
                "conn {token}: register failed: {e}"
            ));
            return;
        }
        self.ctx.log.line(&format!(
            "conn {token}: open ({})",
            if http { "http" } else { "binary" }
        ));
        self.conns.insert(
            token,
            Conn {
                stream,
                fd,
                http,
                rbuf: Vec::new(),
                wbuf: Vec::new(),
                wpos: 0,
                base_slot: 0,
                next_slot: 0,
                slots: VecDeque::new(),
                read_shut: false,
                dead: false,
                interest: Interest::READ,
                last_activity: Instant::now(),
            },
        );
    }

    /// Over-budget connection: answer with an explicit rejection on
    /// the still-blocking accepted socket, then drop it.
    fn shed(&mut self, stream: TcpStream, http: bool) {
        self.ctx.metrics.shed.fetch_add(1, Ordering::Relaxed);
        self.ctx.log.line(&format!(
            "shed connection: at the {} connection budget",
            self.max_connections
        ));
        let msg = "server at connection capacity, retry later";
        let bytes = if http {
            http::encode_response(
                429,
                &http::error_body(msg),
                false,
            )
        } else {
            encode_binary(&Response::Shed(msg.to_string()))
        };
        let _ = stream
            .set_write_timeout(Some(Duration::from_millis(250)));
        let mut s = stream;
        let _ = s.write_all(&bytes);
    }

    // -------------------------------------------------------- parsing

    fn read_and_parse(&mut self, token: Token, hangup: bool) {
        let http = {
            let Some(c) = self.conns.get_mut(&token) else {
                return;
            };
            c.fill_rbuf();
            if hangup {
                c.read_shut = true;
            }
            c.http
        };
        if http {
            self.parse_http(token);
        } else {
            self.parse_binary(token);
        }
    }

    fn parse_binary(&mut self, token: Token) {
        enum Step {
            Frame(u8, Vec<u8>),
            Fatal(String),
            Wait,
        }
        loop {
            let step = {
                let Some(c) = self.conns.get_mut(&token) else {
                    return;
                };
                if c.rbuf.len() < 5 {
                    Step::Wait
                } else {
                    let len = u32::from_le_bytes([
                        c.rbuf[1], c.rbuf[2], c.rbuf[3], c.rbuf[4],
                    ]) as usize;
                    if len > MAX_BODY_BYTES {
                        Step::Fatal(format!(
                            "protocol frame body of {len} bytes \
                             exceeds limit"
                        ))
                    } else if c.rbuf.len() < 5 + len {
                        Step::Wait
                    } else {
                        let op = c.rbuf[0];
                        let body = c.rbuf[5..5 + len].to_vec();
                        c.rbuf.drain(..5 + len);
                        Step::Frame(op, body)
                    }
                }
            };
            match step {
                Step::Wait => return,
                Step::Fatal(msg) => {
                    self.binary_fail(token, msg);
                    return;
                }
                Step::Frame(op, body) => {
                    match decode_request_body(op, &body) {
                        Ok(rq) => self.enqueue_binary(token, rq),
                        Err(e) => {
                            self.binary_fail(
                                token,
                                format!("malformed request: {e}"),
                            );
                            return;
                        }
                    }
                }
            }
        }
    }

    /// Unrecoverable framing error: answer (in slot order), then
    /// close — the stream is desynced past this point.
    fn binary_fail(&mut self, token: Token, msg: String) {
        self.ctx.counters.errors.fetch_add(1, Ordering::Relaxed);
        self.ctx.metrics.errors.fetch_add(1, Ordering::Relaxed);
        self.ctx.log.line(&format!("conn {token}: {msg}"));
        let bytes = encode_binary(&Response::Error(msg));
        self.local_response(token, bytes, true);
    }

    fn parse_http(&mut self, token: Token) {
        enum Step {
            Req(HttpRequest),
            Bad(u16, String),
            Wait,
        }
        loop {
            let step = {
                let Some(c) = self.conns.get_mut(&token) else {
                    return;
                };
                if c.read_shut || c.rbuf.is_empty() {
                    Step::Wait
                } else {
                    match http::parse_request(&c.rbuf) {
                        Parse::Incomplete => Step::Wait,
                        Parse::Bad { status, msg } => {
                            c.read_shut = true;
                            Step::Bad(status, msg)
                        }
                        Parse::Ok(r) => {
                            c.rbuf.drain(..r.consumed);
                            if !r.keep_alive {
                                c.read_shut = true;
                            }
                            Step::Req(r)
                        }
                    }
                }
            };
            match step {
                Step::Wait => return,
                Step::Bad(status, msg) => {
                    self.http_error(token, status, &msg, false);
                    return;
                }
                Step::Req(r) => self.route_http(token, r),
            }
        }
    }

    fn http_error(
        &mut self,
        token: Token,
        status: u16,
        msg: &str,
        keep_alive: bool,
    ) {
        self.ctx.counters.errors.fetch_add(1, Ordering::Relaxed);
        self.ctx.metrics.errors.fetch_add(1, Ordering::Relaxed);
        let bytes = http::encode_response(
            status,
            &http::error_body(msg),
            keep_alive,
        );
        self.local_response(token, bytes, !keep_alive);
    }

    fn route_http(&mut self, token: Token, r: HttpRequest) {
        self.ctx
            .metrics
            .http_requests
            .fetch_add(1, Ordering::Relaxed);
        let keep = r.keep_alive;
        match (r.method.as_str(), r.path.as_str()) {
            // Liveness: the loop thread answered, so the process is
            // up. Never touches the registry — a wedged model load
            // must not fail liveness.
            ("GET", "/healthz") => {
                let body = Value::obj(vec![(
                    "status",
                    Value::Str("ok".into()),
                )])
                .to_string();
                let bytes = http::encode_response(200, &body, keep);
                self.local_response(token, bytes, !keep);
            }
            // Readiness: 200 only while the default model resolves
            // and no drain is in progress; load balancers should
            // route on this, not on /healthz.
            ("GET", "/readyz") => {
                let draining =
                    self.ctx.shutdown.load(Ordering::Relaxed)
                        || sigterm_requested();
                let (status, state) = if draining {
                    (503, "draining")
                } else if resolve_model(&self.ctx, "").is_err() {
                    (503, "default model unavailable")
                } else {
                    (200, "ready")
                };
                let body = Value::obj(vec![(
                    "status",
                    Value::Str(state.into()),
                )])
                .to_string();
                let bytes =
                    http::encode_response(status, &body, keep);
                self.local_response(token, bytes, !keep);
            }
            ("GET", "/metrics") => {
                self.ctx
                    .counters
                    .requests
                    .fetch_add(1, Ordering::Relaxed);
                self.ctx
                    .metrics
                    .requests
                    .fetch_add(1, Ordering::Relaxed);
                let body = self
                    .ctx
                    .metrics
                    .to_json(
                        self.ctx.registry.loads(),
                        self.ctx.registry.hits(),
                        self.ctx.registry.stats_json(),
                    )
                    .to_string();
                let bytes =
                    http::encode_response(200, &body, keep);
                self.local_response(token, bytes, !keep);
            }
            ("GET", "/v1/models") => self.enqueue(
                token,
                Wire::Http { keep_alive: keep },
                String::new(),
                Verb::Info,
                None,
            ),
            ("GET", p) if p.starts_with("/v1/models/") => {
                let name = p["/v1/models/".len()..].to_string();
                self.enqueue(
                    token,
                    Wire::Http { keep_alive: keep },
                    name,
                    Verb::Info,
                    None,
                );
            }
            ("POST", "/v1/predict") => {
                self.http_kernel(token, r, Verb::Predict)
            }
            ("POST", "/v1/compress") => {
                self.http_kernel(token, r, Verb::Compress)
            }
            (
                _,
                "/healthz" | "/readyz" | "/metrics" | "/v1/models"
                | "/v1/predict" | "/v1/compress",
            ) => self.http_error(
                token,
                405,
                "method not allowed for this path",
                keep,
            ),
            _ => self.http_error(
                token,
                404,
                &format!("no route for {}", r.path),
                keep,
            ),
        }
    }

    fn http_kernel(
        &mut self,
        token: Token,
        r: HttpRequest,
        verb: Verb,
    ) {
        let keep = r.keep_alive;
        match parse_kernel_body(&r.body) {
            Ok((model, x)) => self.enqueue(
                token,
                Wire::Http { keep_alive: keep },
                model,
                verb,
                Some(x),
            ),
            Err(e) => {
                self.http_error(token, 400, &e.to_string(), keep)
            }
        }
    }

    // ----------------------------------------------------- dispatch

    fn enqueue_binary(&mut self, token: Token, rq: Request) {
        let (model, verb, x) = match rq {
            Request::ModelInfo { model } => {
                (model, Verb::Info, None)
            }
            Request::Compress { model, x } => {
                (model, Verb::Compress, Some(x))
            }
            Request::Predict { model, x } => {
                (model, Verb::Predict, Some(x))
            }
        };
        self.enqueue(token, Wire::Binary, model, verb, x);
    }

    fn enqueue(
        &mut self,
        token: Token,
        wire: Wire,
        model: String,
        verb: Verb,
        x: Option<FeatureMatrix>,
    ) {
        let slot = {
            let Some(c) = self.conns.get_mut(&token) else {
                return;
            };
            let slot = c.next_slot;
            c.next_slot += 1;
            c.slots.push_back(Slot {
                data: None,
                close_after: matches!(
                    wire,
                    Wire::Http { keep_alive: false }
                ),
            });
            slot
        };
        let pr = PendingReq {
            conn: token,
            slot,
            wire,
            model,
            verb,
            x,
            enqueued: Instant::now(),
        };
        if let Some(batch) = self.batcher.push(pr) {
            self.dispatch(batch);
        }
    }

    /// A response produced on the loop thread itself (parse errors,
    /// `GET /metrics`): fill its slot immediately, in order.
    fn local_response(
        &mut self,
        token: Token,
        bytes: Vec<u8>,
        close_after: bool,
    ) {
        let Some(c) = self.conns.get_mut(&token) else {
            return;
        };
        c.next_slot += 1;
        c.slots.push_back(Slot { data: Some(bytes), close_after });
        c.pump();
    }

    fn dispatch(&mut self, batch: Batch) {
        if self.inflight >= self.max_inflight {
            // the pool's bounded queue is full-ish: hold the batch
            // locally so the loop thread never blocks in submit()
            self.overflow.push_back(batch);
        } else {
            self.submit(batch);
        }
    }

    fn submit(&mut self, batch: Batch) {
        self.inflight += 1;
        self.pool.discard_ready_results();
        let ctx = self.ctx.clone();
        let tx = self.tx.clone();
        let waker = self.wake.waker();
        self.pool.submit(move || {
            let done = execute_batch(&ctx, batch);
            let _ = tx.send(done);
            waker.wake();
        });
    }

    fn drain_completions(&mut self) {
        while let Ok(done) = self.rx.try_recv() {
            self.inflight = self.inflight.saturating_sub(1);
            self.apply_completion(done);
            while self.inflight < self.max_inflight {
                match self.overflow.pop_front() {
                    Some(b) => self.submit(b),
                    None => break,
                }
            }
        }
    }

    fn apply_completion(&mut self, done: Completion) {
        for (token, slot, bytes) in done {
            // monotonic tokens: a completion for a connection that
            // died meanwhile finds nothing and is dropped here
            if let Some(c) = self.conns.get_mut(&token) {
                let idx = slot.wrapping_sub(c.base_slot) as usize;
                if let Some(s) = c.slots.get_mut(idx) {
                    s.data = Some(bytes);
                }
                c.pump();
            }
        }
    }

    // ------------------------------------------------- housekeeping

    /// Push pending output, close finished connections, reap idle
    /// ones past the deadline (ADR-010), and keep every
    /// registration's interest in sync with its state.
    fn flush_and_sweep(&mut self) {
        enum Sweep {
            Keep,
            Close,
            IdleClose,
        }
        let tokens: Vec<Token> =
            self.conns.keys().copied().collect();
        for t in tokens {
            let verdict = match self.conns.get_mut(&t) {
                None => continue,
                Some(c) => {
                    if c.wpos < c.wbuf.len() {
                        c.write_pending();
                    }
                    if c.dead
                        || (c.read_shut
                            && c.slots.is_empty()
                            && c.wpos >= c.wbuf.len())
                    {
                        Sweep::Close
                    } else if self.idle_timeout.is_some_and(|d| {
                        // in-flight work (open slots) exempts a
                        // connection: the response itself will make
                        // progress and reset the clock
                        c.slots.is_empty()
                            && c.last_activity.elapsed() >= d
                    }) {
                        Sweep::IdleClose
                    } else {
                        Sweep::Keep
                    }
                }
            };
            match verdict {
                Sweep::Keep => {}
                Sweep::Close => {
                    self.close_conn(t);
                    continue;
                }
                Sweep::IdleClose => {
                    self.ctx
                        .metrics
                        .idle_closed
                        .fetch_add(1, Ordering::Relaxed);
                    self.ctx.log.line(&format!(
                        "conn {t}: closed by the idle deadline"
                    ));
                    self.close_conn(t);
                    continue;
                }
            }
            if let Some(c) = self.conns.get_mut(&t) {
                let want = Interest {
                    read: !c.read_shut,
                    write: c.wpos < c.wbuf.len(),
                };
                if want != c.interest
                    && self.poller.modify(c.fd, t, want).is_ok()
                {
                    c.interest = want;
                }
            }
        }
    }

    fn close_conn(&mut self, token: Token) {
        if let Some(c) = self.conns.remove(&token) {
            // deregister BEFORE the fd closes on drop, or a recycled
            // descriptor could inherit the stale registration
            let _ = self.poller.remove(c.fd, token);
            self.ctx.log.line(&format!("conn {token}: closed"));
        }
    }

    /// Shutdown path: flush the batcher, drain in-flight jobs,
    /// best-effort write buffered responses, drain the pool.
    fn drain_and_exit(mut self) {
        let rest = self.batcher.drain();
        for b in rest {
            self.dispatch(b);
        }
        let deadline = Instant::now() + Duration::from_secs(5);
        while self.inflight > 0 && Instant::now() < deadline {
            match self
                .rx
                .recv_timeout(Duration::from_millis(100))
            {
                Ok(done) => {
                    self.inflight =
                        self.inflight.saturating_sub(1);
                    self.apply_completion(done);
                    while self.inflight < self.max_inflight {
                        match self.overflow.pop_front() {
                            Some(b) => self.submit(b),
                            None => break,
                        }
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        for c in self.conns.values_mut() {
            if c.wpos < c.wbuf.len() && !c.dead {
                let _ = c.stream.set_nonblocking(false);
                let _ = c.stream.set_write_timeout(Some(
                    Duration::from_millis(250),
                ));
                let _ = c.stream.write_all(&c.wbuf[c.wpos..]);
            }
        }
        self.conns.clear();
        self.ctx.log.line("accept loop exited");
        let _: Vec<()> = self.pool.finish();
        self.ctx.log.line("worker pool drained");
    }
}

// ------------------------------------------------------ batch workers

/// Per-request outcome inside an executed batch.
enum Out {
    Info(String),
    Proba(Vec<f32>),
    Comp(FeatureMatrix),
    Fail(String),
}

/// Execute one coalesced batch on a pool worker and encode every
/// member's response for its wire.
fn execute_batch(ctx: &ServerCtx, batch: Batch) -> Completion {
    let n = batch.reqs.len();
    let model = resolve_model(ctx, &batch.model);
    let outs: Vec<Out> = match &model {
        Err(e) => {
            let msg = e.to_string();
            batch
                .reqs
                .iter()
                .map(|_| Out::Fail(msg.clone()))
                .collect()
        }
        Ok(m) => match batch.verb {
            Verb::Info => {
                // lazy decode: HEAD + FOLD only, shared by the batch
                let info = m.info_json().map(|v| v.to_string());
                batch
                    .reqs
                    .iter()
                    .map(|_| match &info {
                        Ok(s) => Out::Info(s.clone()),
                        Err(e) => Out::Fail(e.to_string()),
                    })
                    .collect()
            }
            Verb::Predict => run_predict(m, &batch.reqs),
            Verb::Compress => run_compress(m, &batch.reqs),
        },
    };
    let n_err = outs
        .iter()
        .filter(|o| matches!(o, Out::Fail(_)))
        .count() as u64;
    ctx.counters.batches.fetch_add(1, Ordering::Relaxed);
    ctx.counters.requests.fetch_add(n as u64, Ordering::Relaxed);
    ctx.metrics.requests.fetch_add(n as u64, Ordering::Relaxed);
    if n_err > 0 {
        ctx.counters.errors.fetch_add(n_err, Ordering::Relaxed);
        ctx.metrics.errors.fetch_add(n_err, Ordering::Relaxed);
    }
    ctx.metrics.record_batch(n);
    ctx.metrics.record_model(&batch.model, n as u64);
    batch
        .reqs
        .iter()
        .zip(outs)
        .map(|(rq, out)| {
            let bytes = encode_out(rq, out);
            ctx.metrics.record_latency_us(
                rq.enqueued.elapsed().as_micros() as u64,
            );
            (rq.conn, rq.slot, bytes)
        })
        .collect()
}

/// One sample-major predict pass over the whole batch, split back
/// per request. Bit-identical to per-request execution because every
/// kernel on the predict path is row-independent; a failure (the
/// dimension check) depends only on the column count the group is
/// keyed on, so error text matches the unbatched path too.
fn run_predict(m: &MappedModel, reqs: &[PendingReq]) -> Vec<Out> {
    if reqs.len() == 1 {
        let x = reqs[0].x.as_ref().expect("kernel verb carries x");
        return vec![match m.predict_proba(x) {
            Ok(p) => Out::Proba(p),
            Err(e) => Out::Fail(e.to_string()),
        }];
    }
    let big = match concat_rows(reqs) {
        Ok(b) => b,
        Err(e) => return fail_all(reqs, &e.to_string()),
    };
    match m.predict_proba(&big) {
        Err(e) => fail_all(reqs, &e.to_string()),
        Ok(p) => {
            let mut off = 0;
            reqs.iter()
                .map(|r| {
                    let rows =
                        r.x.as_ref().expect("kernel x").rows;
                    let part = p[off..off + rows].to_vec();
                    off += rows;
                    Out::Proba(part)
                })
                .collect()
        }
    }
}

/// Same coalescing for compress; the `(c, k)` result splits by row.
fn run_compress(m: &MappedModel, reqs: &[PendingReq]) -> Vec<Out> {
    if reqs.len() == 1 {
        let x = reqs[0].x.as_ref().expect("kernel verb carries x");
        return vec![match m.compress(x) {
            Ok(xk) => Out::Comp(xk),
            Err(e) => Out::Fail(e.to_string()),
        }];
    }
    let big = match concat_rows(reqs) {
        Ok(b) => b,
        Err(e) => return fail_all(reqs, &e.to_string()),
    };
    match m.compress(&big) {
        Err(e) => fail_all(reqs, &e.to_string()),
        Ok(xk) => {
            let k = xk.cols;
            let mut off = 0;
            reqs.iter()
                .map(|r| {
                    let rows =
                        r.x.as_ref().expect("kernel x").rows;
                    let part =
                        xk.data[off * k..(off + rows) * k].to_vec();
                    off += rows;
                    match FeatureMatrix::from_vec(rows, k, part) {
                        Ok(mm) => Out::Comp(mm),
                        Err(e) => Out::Fail(e.to_string()),
                    }
                })
                .collect()
        }
    }
}

fn concat_rows(reqs: &[PendingReq]) -> Result<FeatureMatrix> {
    let cols = reqs[0].x.as_ref().expect("kernel x").cols;
    let total: usize = reqs
        .iter()
        .map(|r| r.x.as_ref().expect("kernel x").rows)
        .sum();
    let mut data = Vec::with_capacity(total * cols);
    for r in reqs {
        data.extend_from_slice(
            &r.x.as_ref().expect("kernel x").data,
        );
    }
    FeatureMatrix::from_vec(total, cols, data)
}

fn fail_all(reqs: &[PendingReq], msg: &str) -> Vec<Out> {
    reqs.iter().map(|_| Out::Fail(msg.to_string())).collect()
}

fn encode_binary(rs: &Response) -> Vec<u8> {
    protocol::encode_response(rs).unwrap_or_else(|e| {
        let fallback =
            Response::Error(format!("response encoding failed: {e}"));
        protocol::encode_response(&fallback).unwrap_or_default()
    })
}

fn encode_out(rq: &PendingReq, out: Out) -> Vec<u8> {
    match rq.wire {
        Wire::Binary => {
            let rs = match out {
                Out::Info(s) => Response::Info(s),
                Out::Proba(p) => Response::Probabilities(p),
                Out::Comp(x) => Response::Compressed(x),
                Out::Fail(msg) => Response::Error(msg),
            };
            encode_binary(&rs)
        }
        Wire::Http { keep_alive } => {
            let (status, body) = match out {
                Out::Info(s) => (200, s),
                Out::Proba(p) => (
                    200,
                    Value::obj(vec![(
                        "proba",
                        Value::nums(
                            p.iter().map(|&v| v as f64),
                        ),
                    )])
                    .to_string(),
                ),
                Out::Comp(x) => (200, matrix_json(&x)),
                Out::Fail(msg) => {
                    (400, http::error_body(&msg))
                }
            };
            http::encode_response(status, &body, keep_alive)
        }
    }
}

/// JSON body of an HTTP compress response. `f32 -> f64 -> shortest
/// decimal` round-trips exactly, so the JSON path preserves bits.
fn matrix_json(x: &FeatureMatrix) -> String {
    let rows: Vec<Value> = (0..x.rows)
        .map(|r| {
            Value::nums(
                x.data[r * x.cols..(r + 1) * x.cols]
                    .iter()
                    .map(|&v| v as f64),
            )
        })
        .collect();
    Value::obj(vec![
        ("rows", Value::Num(x.rows as f64)),
        ("cols", Value::Num(x.cols as f64)),
        ("x", Value::Arr(rows)),
    ])
    .to_string()
}

/// Lazily pull `model` (optional) and `x` (required) out of a
/// predict/compress POST body without building a JSON tree.
fn parse_kernel_body(body: &[u8]) -> Result<(String, FeatureMatrix)> {
    let text = std::str::from_utf8(body)
        .map_err(|_| invalid("request body is not UTF-8"))?;
    let model =
        json::scan_str(text, &["model"])?.unwrap_or_default();
    let Some((rows, cols, data)) =
        json::scan_f32_matrix(text, &["x"])?
    else {
        return Err(invalid(
            "request body needs an \"x\" matrix",
        ));
    };
    let x = FeatureMatrix::from_vec(rows, cols, data)?;
    Ok((model, x))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{
        DataConfig, EstimatorConfig, Method, ReduceConfig,
    };
    use crate::model::{fit_model, save_model, FitOptions};
    use crate::serve::ServeClient;
    use crate::volume::MorphometryGenerator;

    fn saved_model(tag: &str) -> (PathBuf, crate::model::FittedModel) {
        let dc = DataConfig {
            dims: [8, 9, 7],
            n_samples: 24,
            seed: 3,
            ..Default::default()
        };
        let (ds, y) =
            MorphometryGenerator::new(dc.dims).generate(dc.n_samples, 3);
        let reduce = ReduceConfig {
            method: Method::Fast,
            ratio: 10,
            ..Default::default()
        };
        let est = EstimatorConfig {
            cv_folds: 3,
            max_iter: 60,
            ..Default::default()
        };
        let model = fit_model(
            &ds,
            &y,
            &reduce,
            &est,
            &dc,
            &FitOptions::default(),
        )
        .unwrap();
        let dir = std::env::temp_dir().join("fastclust_serve_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{tag}.fcm"));
        save_model(&path, &model).unwrap();
        (path, model)
    }

    #[test]
    fn start_rejects_missing_model() {
        let opts = ServeOptions::new("/nonexistent/m.fcm");
        assert!(Server::start(opts).is_err());
    }

    #[test]
    fn single_client_info_and_predict() {
        let (path, model) = saved_model("single");
        let mut opts = ServeOptions::new(&path);
        opts.workers = 2;
        let handle = Server::start(opts).unwrap();
        let mut client = ServeClient::connect(handle.addr()).unwrap();
        let info = client.model_info().unwrap();
        assert_eq!(
            info.get("k").unwrap().as_usize().unwrap(),
            model.header.k
        );
        // one synthetic sample, compared against the offline path
        let x = crate::volume::FeatureMatrix::from_vec(
            1,
            model.header.p,
            (0..model.header.p).map(|i| (i % 7) as f32).collect(),
        )
        .unwrap();
        let want = model.predict_proba(&x).unwrap();
        let got = client.predict(&x).unwrap();
        assert_eq!(got, want, "served == offline, bit-identical");
        // dimension mismatch must come back as a protocol error
        let bad = crate::volume::FeatureMatrix::zeros(1, 3);
        assert!(client.predict(&bad).is_err());
        drop(client);
        let stats = handle.shutdown().unwrap();
        assert_eq!(stats.connections, 1);
        assert!(stats.requests >= 3);
        assert_eq!(stats.errors, 1);
    }

    #[test]
    fn illegal_model_names_rejected() {
        let (path, _) = saved_model("names");
        let mut opts = ServeOptions::new(&path);
        opts.workers = 1;
        let handle = Server::start(opts).unwrap();
        let mut client = ServeClient::connect(handle.addr()).unwrap();
        for bad in ["../evil.fcm", "a/b.fcm", ".hidden"] {
            assert!(
                client.model_info_named(bad).is_err(),
                "name '{bad}' must be rejected"
            );
        }
        drop(client);
        handle.shutdown().unwrap();
    }

    /// Blocking mini HTTP client: one request, one full response.
    fn http_call(
        stream: &mut TcpStream,
        req: &str,
    ) -> (u16, String) {
        stream.write_all(req.as_bytes()).unwrap();
        let mut head = Vec::new();
        let mut byte = [0u8; 1];
        while !head.ends_with(b"\r\n\r\n") {
            stream.read_exact(&mut byte).unwrap();
            head.push(byte[0]);
        }
        let head = String::from_utf8(head).unwrap();
        let status: u16 = head
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .expect("status line");
        let clen: usize = head
            .lines()
            .find_map(|l| {
                let (k, v) = l.split_once(':')?;
                k.eq_ignore_ascii_case("content-length")
                    .then(|| v.trim().parse().ok())?
            })
            .expect("content-length header");
        let mut body = vec![0u8; clen];
        stream.read_exact(&mut body).unwrap();
        (status, String::from_utf8(body).unwrap())
    }

    #[test]
    fn http_gateway_serves_metrics_and_predict() {
        let (path, model) = saved_model("http");
        let mut opts = ServeOptions::new(&path);
        opts.workers = 2;
        opts.http_port = Some(0);
        let handle = Server::start(opts).unwrap();
        let http_addr = handle.http_addr().expect("gateway bound");
        let mut s = TcpStream::connect(http_addr).unwrap();
        let (code, body) = http_call(
            &mut s,
            "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n",
        );
        assert_eq!(code, 200);
        let v = crate::json::parse(&body).unwrap();
        assert!(v.get("accepted").unwrap().as_u64().unwrap() >= 1);
        // JSON predict must preserve f32 bits end to end
        let p = model.header.p;
        let x = FeatureMatrix::from_vec(
            1,
            p,
            (0..p).map(|i| (i % 7) as f32).collect(),
        )
        .unwrap();
        let want = model.predict_proba(&x).unwrap();
        let row: Vec<String> = x
            .data
            .iter()
            .map(|&v| format!("{}", v as f64))
            .collect();
        let body_json = format!("{{\"x\":[[{}]]}}", row.join(","));
        let req = format!(
            "POST /v1/predict HTTP/1.1\r\nContent-Length: {}\
             \r\n\r\n{}",
            body_json.len(),
            body_json
        );
        let (code, body) = http_call(&mut s, &req);
        assert_eq!(code, 200, "predict failed: {body}");
        let v = crate::json::parse(&body).unwrap();
        let got: Vec<f32> = v
            .get("proba")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|n| n.as_f64().unwrap() as f32)
            .collect();
        assert_eq!(got, want, "HTTP JSON path preserves f32 bits");
        // unknown route on the same keep-alive connection
        let (code, _) =
            http_call(&mut s, "GET /nope HTTP/1.1\r\n\r\n");
        assert_eq!(code, 404);
        // liveness + readiness probes (ADR-010)
        let (code, body) = http_call(
            &mut s,
            "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n",
        );
        assert_eq!(code, 200);
        assert!(body.contains("ok"), "healthz body: {body}");
        let (code, body) = http_call(
            &mut s,
            "GET /readyz HTTP/1.1\r\nHost: t\r\n\r\n",
        );
        assert_eq!(code, 200);
        assert!(body.contains("ready"), "readyz body: {body}");
        // a known path with the wrong method is 405, not 404
        let (code, _) = http_call(
            &mut s,
            "POST /healthz HTTP/1.1\r\nContent-Length: 0\r\n\r\n",
        );
        assert_eq!(code, 405);
        drop(s);
        handle.shutdown().unwrap();
    }

    #[test]
    fn idle_deadline_reaps_quiet_connections() {
        let (path, _) = saved_model("idle");
        let mut opts = ServeOptions::new(&path);
        opts.workers = 1;
        opts.max_connections = 1;
        opts.idle_timeout_ms = 300;
        let handle = Server::start(opts).unwrap();
        // a slow-loris peer: connects, sends half a frame, goes quiet
        let mut loris = TcpStream::connect(handle.addr()).unwrap();
        loris.write_all(&[1u8, 0, 0]).unwrap();
        loris
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let mut buf = [0u8; 16];
        // the server must close it (EOF or reset) within the
        // deadline — well before the client-side read timeout, which
        // would also surface as Err
        let t0 = Instant::now();
        let reaped = matches!(loris.read(&mut buf), Ok(0) | Err(_));
        assert!(reaped, "idle connection was never reaped");
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "close came from the client timeout, not the reaper"
        );
        // the budget slot it held is free again: a fresh client gets
        // admitted and served on a budget of 1
        let mut client =
            ServeClient::connect(handle.addr()).unwrap();
        client.model_info().unwrap();
        drop(client);
        let m = handle.metrics_json();
        assert!(
            m.get("idle_closed").unwrap().as_u64().unwrap() >= 1,
            "idle_closed counter never moved"
        );
        handle.shutdown().unwrap();
    }

    #[test]
    fn connection_budget_sheds_explicitly() {
        let (path, _) = saved_model("shed");
        let mut opts = ServeOptions::new(&path);
        opts.workers = 1;
        opts.max_connections = 1;
        let handle = Server::start(opts).unwrap();
        let mut first = ServeClient::connect(handle.addr()).unwrap();
        first.model_info().unwrap(); // guarantees admission landed
        let mut second =
            ServeClient::connect(handle.addr()).unwrap();
        let err = second.model_info().unwrap_err();
        assert!(
            err.to_string().contains("capacity"),
            "expected an explicit shed, got: {err}"
        );
        let m = handle.metrics_json();
        assert_eq!(m.get("shed").unwrap().as_u64().unwrap(), 1);
        drop(first);
        drop(second);
        handle.shutdown().unwrap();
    }
}
