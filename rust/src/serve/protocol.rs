//! The wire protocol of the decode server (ADR-004 §Serving): a
//! length-prefixed binary framing over TCP, little-endian throughout.
//!
//! # Request frame
//!
//! ```text
//! opcode  u8    1 = model-info, 2 = compress, 3 = predict
//! len     u32   body length in bytes
//! body:
//!   model str   u32 byte length + UTF-8 model name ("" = the
//!               server's default model; otherwise resolved inside
//!               the server's model directory via the LRU cache)
//!   compress/predict only:
//!     c  u32    samples in the block
//!     p  u32    voxels per sample
//!     x  c*p f32  sample-major payload (row = one sample)
//! ```
//!
//! # Response frame
//!
//! ```text
//! opcode  u8    echoes the request opcode; 0xFF = error
//! len     u32   body length in bytes
//! body:
//!   model-info: UTF-8 JSON ([`crate::model::FittedModel::info_json`])
//!   compress:   c u32, k u32, x c*k f32 (sample-major)
//!   predict:    c u32, proba c*f32 (ensemble P(class 1) per sample)
//!   error:      UTF-8 message
//! ```
//!
//! Requests on one connection are answered in order, so clients may
//! pipeline frames back-to-back — that is exactly what the server's
//! per-connection batching exploits.

use std::io::{ErrorKind, Read, Write};

use crate::error::{invalid, Result};
use crate::volume::FeatureMatrix;

/// Request opcodes on the wire.
pub const OP_MODEL_INFO: u8 = 1;
/// Compress a sample block.
pub const OP_COMPRESS: u8 = 2;
/// Predict on a sample block.
pub const OP_PREDICT: u8 = 3;
/// Response opcode marking a server-side error.
pub const OP_ERROR: u8 = 0xFF;

/// Largest frame body accepted (corruption / abuse guard).
const MAX_BODY_BYTES: usize = 1 << 28;

/// One decoded client request.
#[derive(Clone, Debug)]
pub enum Request {
    /// Summarize a resident model.
    ModelInfo {
        /// Model name ("" = server default).
        model: String,
    },
    /// Reduce a `(c, p)` sample-major block to `(c, k)`.
    Compress {
        /// Model name ("" = server default).
        model: String,
        /// The sample block.
        x: FeatureMatrix,
    },
    /// Ensemble class-1 probability for a `(c, p)` block.
    Predict {
        /// Model name ("" = server default).
        model: String,
        /// The sample block.
        x: FeatureMatrix,
    },
}

/// One server response.
#[derive(Clone, Debug)]
pub enum Response {
    /// JSON model summary.
    Info(String),
    /// `(c, k)` reduced features.
    Compressed(FeatureMatrix),
    /// Per-sample ensemble probabilities.
    Probabilities(Vec<f32>),
    /// Request-level failure (the connection stays usable unless the
    /// frame itself was malformed).
    Error(String),
}

// ------------------------------------------------------------- encode

fn put_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

fn put_matrix(buf: &mut Vec<u8>, x: &FeatureMatrix) {
    buf.extend_from_slice(&(x.rows as u32).to_le_bytes());
    buf.extend_from_slice(&(x.cols as u32).to_le_bytes());
    for &v in &x.data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn write_frame(w: &mut impl Write, opcode: u8, body: &[u8]) -> Result<()> {
    // symmetric with the read-side guard: an oversized body must be
    // an immediate error, not a wrapped u32 length that desyncs the
    // stream on the other end
    if body.len() > MAX_BODY_BYTES {
        return Err(invalid(format!(
            "frame body of {} bytes exceeds the protocol limit",
            body.len()
        )));
    }
    w.write_all(&[opcode])?;
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    Ok(())
}

/// Encode + write one request frame (no flush).
pub fn write_request(w: &mut impl Write, rq: &Request) -> Result<()> {
    let mut body = Vec::new();
    let opcode = match rq {
        Request::ModelInfo { model } => {
            put_str(&mut body, model);
            OP_MODEL_INFO
        }
        Request::Compress { model, x } => {
            put_str(&mut body, model);
            put_matrix(&mut body, x);
            OP_COMPRESS
        }
        Request::Predict { model, x } => {
            put_str(&mut body, model);
            put_matrix(&mut body, x);
            OP_PREDICT
        }
    };
    write_frame(w, opcode, &body)
}

/// Encode + write one response frame (no flush).
pub fn write_response(w: &mut impl Write, rs: &Response) -> Result<()> {
    let mut body = Vec::new();
    let opcode = match rs {
        Response::Info(json) => {
            body.extend_from_slice(json.as_bytes());
            OP_MODEL_INFO
        }
        Response::Compressed(x) => {
            put_matrix(&mut body, x);
            OP_COMPRESS
        }
        Response::Probabilities(p) => {
            body.extend_from_slice(&(p.len() as u32).to_le_bytes());
            for &v in p {
                body.extend_from_slice(&v.to_le_bytes());
            }
            OP_PREDICT
        }
        Response::Error(msg) => {
            body.extend_from_slice(msg.as_bytes());
            OP_ERROR
        }
    };
    write_frame(w, opcode, &body)
}

// ------------------------------------------------------------- decode

/// Cursor over a frame body.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(invalid("protocol frame truncated"));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        String::from_utf8(self.take(len)?.to_vec())
            .map_err(|_| invalid("protocol string is not UTF-8"))
    }

    fn matrix(&mut self) -> Result<FeatureMatrix> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let bytes = rows
            .checked_mul(cols)
            .and_then(|e| e.checked_mul(4))
            .filter(|&b| b <= MAX_BODY_BYTES)
            .ok_or_else(|| invalid("protocol matrix too large"))?;
        let raw = self.take(bytes)?;
        let data = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        FeatureMatrix::from_vec(rows, cols, data)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(invalid("protocol frame has trailing bytes"));
        }
        Ok(())
    }
}

/// Read one opcode byte. `Ok(None)` = clean EOF (client hung up
/// between frames). Timeouts (`WouldBlock` / `TimedOut`) surface as
/// `Err` so the server's idle loop can poll its shutdown flag.
pub fn read_opcode(r: &mut impl Read) -> std::io::Result<Option<u8>> {
    let mut op = [0u8; 1];
    loop {
        match r.read(&mut op) {
            Ok(0) => return Ok(None),
            Ok(_) => return Ok(Some(op[0])),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
}

fn read_body(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len > MAX_BODY_BYTES {
        return Err(invalid(format!(
            "protocol frame body of {len} bytes exceeds limit"
        )));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(body)
}

/// Read + decode the remainder of a request whose opcode was already
/// consumed (the server reads opcodes separately to keep its idle
/// wait interruptible).
pub fn read_request_body(r: &mut impl Read, opcode: u8) -> Result<Request> {
    let body = read_body(r)?;
    let mut c = Cursor { buf: &body, pos: 0 };
    let rq = match opcode {
        OP_MODEL_INFO => Request::ModelInfo { model: c.str()? },
        OP_COMPRESS => {
            Request::Compress { model: c.str()?, x: c.matrix()? }
        }
        OP_PREDICT => Request::Predict { model: c.str()?, x: c.matrix()? },
        other => {
            return Err(invalid(format!(
                "unknown request opcode {other:#04x}"
            )))
        }
    };
    c.finish()?;
    Ok(rq)
}

/// Read one full request frame; `Ok(None)` = clean EOF.
pub fn read_request(r: &mut impl Read) -> Result<Option<Request>> {
    match read_opcode(r)? {
        None => Ok(None),
        Some(op) => read_request_body(r, op).map(Some),
    }
}

/// Read + decode one response frame.
pub fn read_response(r: &mut impl Read) -> Result<Response> {
    let mut op = [0u8; 1];
    r.read_exact(&mut op)?;
    let body = read_body(r)?;
    let mut c = Cursor { buf: &body, pos: 0 };
    let rs = match op[0] {
        OP_MODEL_INFO => {
            let json = String::from_utf8(body.clone())
                .map_err(|_| invalid("info response is not UTF-8"))?;
            return Ok(Response::Info(json));
        }
        OP_COMPRESS => Response::Compressed(c.matrix()?),
        OP_PREDICT => Response::Probabilities(c.f32s()?),
        OP_ERROR => {
            let msg = String::from_utf8_lossy(&body).into_owned();
            return Ok(Response::Error(msg));
        }
        other => {
            return Err(invalid(format!(
                "unknown response opcode {other:#04x}"
            )))
        }
    };
    c.finish()?;
    Ok(rs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_request(rq: &Request) -> Request {
        let mut buf = Vec::new();
        write_request(&mut buf, rq).unwrap();
        let mut r = &buf[..];
        let back = read_request(&mut r).unwrap().unwrap();
        assert!(r.is_empty(), "request frame fully consumed");
        back
    }

    #[test]
    fn request_frames_roundtrip() {
        match roundtrip_request(&Request::ModelInfo { model: "m".into() })
        {
            Request::ModelInfo { model } => assert_eq!(model, "m"),
            other => panic!("wrong request: {other:?}"),
        }
        let x = FeatureMatrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.])
            .unwrap();
        match roundtrip_request(&Request::Predict {
            model: String::new(),
            x: x.clone(),
        }) {
            Request::Predict { model, x: back } => {
                assert!(model.is_empty());
                assert_eq!(back.data, x.data);
                assert_eq!((back.rows, back.cols), (2, 3));
            }
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn response_frames_roundtrip() {
        let mut buf = Vec::new();
        write_response(&mut buf, &Response::Probabilities(vec![0.25, 1.0]))
            .unwrap();
        write_response(&mut buf, &Response::Error("boom".into())).unwrap();
        let mut r = &buf[..];
        match read_response(&mut r).unwrap() {
            Response::Probabilities(p) => assert_eq!(p, vec![0.25, 1.0]),
            other => panic!("wrong response: {other:?}"),
        }
        match read_response(&mut r).unwrap() {
            Response::Error(msg) => assert_eq!(msg, "boom"),
            other => panic!("wrong response: {other:?}"),
        }
        assert!(r.is_empty());
    }

    #[test]
    fn eof_between_frames_is_clean() {
        let mut r: &[u8] = &[];
        assert!(read_request(&mut r).unwrap().is_none());
    }

    #[test]
    fn malformed_frames_rejected() {
        // unknown opcode
        let mut r: &[u8] = &[9, 0, 0, 0, 0];
        assert!(read_request(&mut r).is_err());
        // truncated body
        let mut buf = Vec::new();
        write_request(
            &mut buf,
            &Request::ModelInfo { model: "x".into() },
        )
        .unwrap();
        buf.pop();
        let mut r = &buf[..];
        assert!(read_request(&mut r).is_err());
        // trailing garbage inside the body
        let mut body = Vec::new();
        put_str(&mut body, "");
        body.push(7);
        let mut buf = Vec::new();
        write_frame(&mut buf, OP_MODEL_INFO, &body).unwrap();
        let mut r = &buf[..];
        assert!(read_request(&mut r).is_err());
    }
}
