//! The wire protocol of the decode server (ADR-004 §Serving): a
//! length-prefixed binary framing over TCP, little-endian throughout.
//!
//! # Request frame
//!
//! ```text
//! opcode  u8    1 = model-info, 2 = compress, 3 = predict
//! len     u32   body length in bytes
//! body:
//!   model str   u32 byte length + UTF-8 model name ("" = the
//!               server's default model; otherwise resolved inside
//!               the server's model directory via the LRU cache)
//!   compress/predict only:
//!     c  u32    samples in the block
//!     p  u32    voxels per sample
//!     x  c*p f32  sample-major payload (row = one sample)
//! ```
//!
//! # Response frame
//!
//! ```text
//! opcode  u8    echoes the request opcode; 0xFF = error
//! len     u32   body length in bytes
//! body:
//!   model-info: UTF-8 JSON ([`crate::model::FittedModel::info_json`])
//!   compress:   c u32, k u32, x c*k f32 (sample-major)
//!   predict:    c u32, proba c*f32 (ensemble P(class 1) per sample)
//!   error:      UTF-8 message
//! ```
//!
//! Requests on one connection are answered in order, so clients may
//! pipeline frames back-to-back — that is exactly what the server's
//! per-connection batching exploits.
//!
//! # Distributed frames (ADR-006, ADR-009)
//!
//! The distributed fit reuses the same `opcode u8 + len u32 + body`
//! framing for six coordinator/worker frames:
//!
//! ```text
//! ASSIGN  (4)  coordinator → worker  job u64, crc u32, payload
//! PARTIAL (5)  worker → coordinator  job u64, seq u32, crc u32, payload
//! ACK     (6)  worker → coordinator  job u64, kind u8, info u64
//! RETRY   (7)  worker → coordinator  job u64, reason str
//! FETCH   (8)  worker → coordinator  job u64, col0 u32, count u32
//! DATA    (9)  coordinator → worker  job u64, col0 u32, crc u32, payload
//! ```
//!
//! `crc` is the CRC-32 of the opaque payload (same polynomial as the
//! `.fcm` section checksums), so a corrupted PARTIAL fails at decode
//! and the coordinator requeues the range instead of merging bad
//! bits. FETCH/DATA are the `.fcd` range-serving pair (ADR-009):
//! a worker without shared storage asks for a column range of its
//! job's data slice and the coordinator streams the block back —
//! the row set is implicit in the job, so requests stay fixed-size.
//! A FETCH itself carries no checksum; the worker instead verifies
//! the DATA echo (`col0`) and the served block's dimensions against
//! what it asked for, and the DATA payload is CRC-stamped, so a
//! corrupted request or reply is always caught before any byte of it
//! feeds a computation. Payload semantics live in
//! [`crate::coordinator::distributed`]; this module owns framing and
//! integrity only, which keeps every decode path reachable from the
//! `protocol_fuzz` suite.

use std::io::{ErrorKind, Read, Write};

use crate::error::{invalid, Result};
use crate::model::format::crc32;
use crate::volume::FeatureMatrix;

/// Request opcodes on the wire.
pub const OP_MODEL_INFO: u8 = 1;
/// Compress a sample block.
pub const OP_COMPRESS: u8 = 2;
/// Predict on a sample block.
pub const OP_PREDICT: u8 = 3;
/// Response opcode marking a server-side error.
pub const OP_ERROR: u8 = 0xFF;
/// Response opcode for load shedding (ADR-007): the server is at its
/// connection budget and rejected the connection *explicitly* — the
/// 429 of the binary protocol, never a silent drop.
pub const OP_SHED: u8 = 0xFE;

/// Coordinator → worker: one job assignment (ADR-006).
pub const OP_ASSIGN: u8 = 4;
/// Worker → coordinator: one partial result of the current job.
pub const OP_PARTIAL: u8 = 5;
/// Worker → coordinator: control frame (done / heartbeat / hello).
pub const OP_ACK: u8 = 6;
/// Worker → coordinator: recoverable failure, reassign the job.
pub const OP_RETRY: u8 = 7;
/// Worker → coordinator: request a column range of the current job's
/// data slice (ADR-009 range serving).
pub const OP_FETCH: u8 = 8;
/// Coordinator → worker: one served data block answering a FETCH.
pub const OP_DATA: u8 = 9;

/// [`DistFrame::Ack`] kind: the job finished; `info` = partial
/// frames the worker believes it sent (the coordinator cross-checks).
pub const ACK_DONE: u8 = 0;
/// [`DistFrame::Ack`] kind: liveness beacon while computing.
pub const ACK_HEARTBEAT: u8 = 1;
/// [`DistFrame::Ack`] kind: connection greeting; `info` = worker pid.
pub const ACK_HELLO: u8 = 2;

/// Largest frame body accepted (corruption / abuse guard). Shared
/// with the event loop's in-buffer frame parser, which enforces the
/// same bound before a body is ever buffered.
pub(crate) const MAX_BODY_BYTES: usize = 1 << 28;

/// One decoded client request.
#[derive(Clone, Debug)]
pub enum Request {
    /// Summarize a resident model.
    ModelInfo {
        /// Model name ("" = server default).
        model: String,
    },
    /// Reduce a `(c, p)` sample-major block to `(c, k)`.
    Compress {
        /// Model name ("" = server default).
        model: String,
        /// The sample block.
        x: FeatureMatrix,
    },
    /// Ensemble class-1 probability for a `(c, p)` block.
    Predict {
        /// Model name ("" = server default).
        model: String,
        /// The sample block.
        x: FeatureMatrix,
    },
}

/// One server response.
#[derive(Clone, Debug)]
pub enum Response {
    /// JSON model summary.
    Info(String),
    /// `(c, k)` reduced features.
    Compressed(FeatureMatrix),
    /// Per-sample ensemble probabilities.
    Probabilities(Vec<f32>),
    /// Request-level failure (the connection stays usable unless the
    /// frame itself was malformed).
    Error(String),
    /// Connection-level rejection: the server is at its connection
    /// budget. Sent once on accept, then the connection is closed —
    /// clients should back off and retry.
    Shed(String),
}

/// One coordinator/worker frame of the distributed fit (ADR-006).
/// `payload` bytes are opaque at this layer — encoded and decoded by
/// [`crate::coordinator::distributed`] — but checksummed here, so
/// corruption is caught before any payload is interpreted.
#[derive(Clone, Debug)]
pub enum DistFrame {
    /// Coordinator → worker: compute job `job` from `payload`.
    Assign {
        /// Coordinator-unique job id (echoed by every reply).
        job: u64,
        /// Encoded job description.
        payload: Vec<u8>,
    },
    /// Worker → coordinator: one partial result of job `job`.
    Partial {
        /// Job this partial belongs to.
        job: u64,
        /// 0-based send sequence within the job.
        seq: u32,
        /// Encoded partial result.
        payload: Vec<u8>,
    },
    /// Worker → coordinator: control frame ([`ACK_DONE`],
    /// [`ACK_HEARTBEAT`] or [`ACK_HELLO`]).
    Ack {
        /// Job the ack refers to (hello/heartbeat: informational).
        job: u64,
        /// One of the `ACK_*` kinds.
        kind: u8,
        /// Kind-specific detail (done: partials sent; hello: pid).
        info: u64,
    },
    /// Worker → coordinator: the job failed recoverably on this
    /// worker (e.g. an unreadable `.fcd` path); reassign it.
    Retry {
        /// The declined job.
        job: u64,
        /// Human-readable cause, recorded in the event log.
        reason: String,
    },
    /// Worker → coordinator: serve `count` sample columns starting at
    /// `col0` of job `job`'s data slice (the row set is implicit in
    /// the job — ADR-009 range serving).
    Fetch {
        /// Job whose data slice is being read.
        job: u64,
        /// First sample column requested.
        col0: u32,
        /// Number of sample columns requested.
        count: u32,
    },
    /// Coordinator → worker: one data block answering a
    /// [`DistFrame::Fetch`]. The worker cross-checks `col0` and the
    /// decoded block's dimensions against its request, so a mangled
    /// FETCH cannot silently feed it the wrong slice.
    Data {
        /// Job the block belongs to.
        job: u64,
        /// Echo of the request's first column.
        col0: u32,
        /// Encoded data block (checksummed like a PARTIAL payload).
        payload: Vec<u8>,
    },
}

// ------------------------------------------------------------- encode

pub(crate) fn put_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

pub(crate) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    put_u32(buf, xs.len() as u32);
    for &v in xs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

pub(crate) fn put_matrix(buf: &mut Vec<u8>, x: &FeatureMatrix) {
    buf.extend_from_slice(&(x.rows as u32).to_le_bytes());
    buf.extend_from_slice(&(x.cols as u32).to_le_bytes());
    for &v in &x.data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn write_frame(w: &mut impl Write, opcode: u8, body: &[u8]) -> Result<()> {
    // symmetric with the read-side guard: an oversized body must be
    // an immediate error, not a wrapped u32 length that desyncs the
    // stream on the other end
    if body.len() > MAX_BODY_BYTES {
        return Err(invalid(format!(
            "frame body of {} bytes exceeds the protocol limit",
            body.len()
        )));
    }
    w.write_all(&[opcode])?;
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    Ok(())
}

/// Encode + write one request frame (no flush).
pub fn write_request(w: &mut impl Write, rq: &Request) -> Result<()> {
    let mut body = Vec::new();
    let opcode = match rq {
        Request::ModelInfo { model } => {
            put_str(&mut body, model);
            OP_MODEL_INFO
        }
        Request::Compress { model, x } => {
            put_str(&mut body, model);
            put_matrix(&mut body, x);
            OP_COMPRESS
        }
        Request::Predict { model, x } => {
            put_str(&mut body, model);
            put_matrix(&mut body, x);
            OP_PREDICT
        }
    };
    write_frame(w, opcode, &body)
}

/// Encode + write one response frame (no flush).
pub fn write_response(w: &mut impl Write, rs: &Response) -> Result<()> {
    let mut body = Vec::new();
    let opcode = match rs {
        Response::Info(json) => {
            body.extend_from_slice(json.as_bytes());
            OP_MODEL_INFO
        }
        Response::Compressed(x) => {
            put_matrix(&mut body, x);
            OP_COMPRESS
        }
        Response::Probabilities(p) => {
            body.extend_from_slice(&(p.len() as u32).to_le_bytes());
            for &v in p {
                body.extend_from_slice(&v.to_le_bytes());
            }
            OP_PREDICT
        }
        Response::Error(msg) => {
            body.extend_from_slice(msg.as_bytes());
            OP_ERROR
        }
        Response::Shed(msg) => {
            body.extend_from_slice(msg.as_bytes());
            OP_SHED
        }
    };
    write_frame(w, opcode, &body)
}

/// Encode + write one distributed frame (no flush). ASSIGN/PARTIAL
/// payloads are stamped with their CRC-32 so the receiving side can
/// reject corruption before interpreting a byte.
pub fn write_dist_frame(w: &mut impl Write, f: &DistFrame) -> Result<()> {
    let mut body = Vec::new();
    let opcode = match f {
        DistFrame::Assign { job, payload } => {
            put_u64(&mut body, *job);
            put_u32(&mut body, crc32(payload));
            body.extend_from_slice(payload);
            OP_ASSIGN
        }
        DistFrame::Partial { job, seq, payload } => {
            put_u64(&mut body, *job);
            put_u32(&mut body, *seq);
            put_u32(&mut body, crc32(payload));
            body.extend_from_slice(payload);
            OP_PARTIAL
        }
        DistFrame::Ack { job, kind, info } => {
            put_u64(&mut body, *job);
            body.push(*kind);
            put_u64(&mut body, *info);
            OP_ACK
        }
        DistFrame::Retry { job, reason } => {
            put_u64(&mut body, *job);
            put_str(&mut body, reason);
            OP_RETRY
        }
        DistFrame::Fetch { job, col0, count } => {
            put_u64(&mut body, *job);
            put_u32(&mut body, *col0);
            put_u32(&mut body, *count);
            OP_FETCH
        }
        DistFrame::Data { job, col0, payload } => {
            put_u64(&mut body, *job);
            put_u32(&mut body, *col0);
            put_u32(&mut body, crc32(payload));
            body.extend_from_slice(payload);
            OP_DATA
        }
    };
    write_frame(w, opcode, &body)
}

// ------------------------------------------------------------- decode

/// Cursor over a frame body (also reused by the distributed job /
/// partial payload codecs in [`crate::coordinator::distributed`]).
pub(crate) struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(invalid("protocol frame truncated"));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        let mut raw = [0u8; 8];
        raw.copy_from_slice(b);
        Ok(u64::from_le_bytes(raw))
    }

    pub(crate) fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Everything not yet consumed (opaque trailing payload).
    pub(crate) fn rest(&mut self) -> &'a [u8] {
        let out = &self.buf[self.pos..];
        self.pos = self.buf.len();
        out
    }

    pub(crate) fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        String::from_utf8(self.take(len)?.to_vec())
            .map_err(|_| invalid("protocol string is not UTF-8"))
    }

    pub(crate) fn matrix(&mut self) -> Result<FeatureMatrix> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let bytes = rows
            .checked_mul(cols)
            .and_then(|e| e.checked_mul(4))
            .filter(|&b| b <= MAX_BODY_BYTES)
            .ok_or_else(|| invalid("protocol matrix too large"))?;
        let raw = self.take(bytes)?;
        let data = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        FeatureMatrix::from_vec(rows, cols, data)
    }

    pub(crate) fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub(crate) fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(invalid("protocol frame has trailing bytes"));
        }
        Ok(())
    }
}

/// Read one opcode byte. `Ok(None)` = clean EOF (client hung up
/// between frames). Timeouts (`WouldBlock` / `TimedOut`) surface as
/// `Err` so the server's idle loop can poll its shutdown flag.
pub fn read_opcode(r: &mut impl Read) -> std::io::Result<Option<u8>> {
    let mut op = [0u8; 1];
    loop {
        match r.read(&mut op) {
            Ok(0) => return Ok(None),
            Ok(_) => return Ok(Some(op[0])),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
}

fn read_body(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len > MAX_BODY_BYTES {
        return Err(invalid(format!(
            "protocol frame body of {len} bytes exceeds limit"
        )));
    }
    // the claimed length is untrusted input: read through a capped
    // `take` so a frame advertising a huge body fails after the bytes
    // actually present, never after a quarter-gigabyte upfront alloc
    let mut body = Vec::with_capacity(len.min(1 << 16));
    let got = r.take(len as u64).read_to_end(&mut body)?;
    if got != len {
        return Err(invalid(format!(
            "protocol frame truncated: body has {got} of {len} bytes"
        )));
    }
    Ok(body)
}

/// Read + decode the remainder of a request whose opcode was already
/// consumed (the server reads opcodes separately to keep its idle
/// wait interruptible).
pub fn read_request_body(r: &mut impl Read, opcode: u8) -> Result<Request> {
    let body = read_body(r)?;
    decode_request_body(opcode, &body)
}

/// Decode a request whose complete body is already in memory — the
/// event-loop server parses frames out of its connection read buffer
/// and never goes through a `Read` adapter.
pub(crate) fn decode_request_body(
    opcode: u8,
    body: &[u8],
) -> Result<Request> {
    let mut c = Cursor { buf: body, pos: 0 };
    let rq = match opcode {
        OP_MODEL_INFO => Request::ModelInfo { model: c.str()? },
        OP_COMPRESS => {
            Request::Compress { model: c.str()?, x: c.matrix()? }
        }
        OP_PREDICT => Request::Predict { model: c.str()?, x: c.matrix()? },
        other => {
            return Err(invalid(format!(
                "unknown request opcode {other:#04x}"
            )))
        }
    };
    c.finish()?;
    Ok(rq)
}

/// Encode one response to bytes (what worker jobs hand back to the
/// event loop for demuxing onto connections).
pub fn encode_response(rs: &Response) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    write_response(&mut buf, rs)?;
    Ok(buf)
}

/// Read one full request frame; `Ok(None)` = clean EOF.
pub fn read_request(r: &mut impl Read) -> Result<Option<Request>> {
    match read_opcode(r)? {
        None => Ok(None),
        Some(op) => read_request_body(r, op).map(Some),
    }
}

/// Read + decode one response frame.
pub fn read_response(r: &mut impl Read) -> Result<Response> {
    let mut op = [0u8; 1];
    r.read_exact(&mut op)?;
    let body = read_body(r)?;
    let mut c = Cursor { buf: &body, pos: 0 };
    let rs = match op[0] {
        OP_MODEL_INFO => {
            let json = String::from_utf8(body.clone())
                .map_err(|_| invalid("info response is not UTF-8"))?;
            return Ok(Response::Info(json));
        }
        OP_COMPRESS => Response::Compressed(c.matrix()?),
        OP_PREDICT => Response::Probabilities(c.f32s()?),
        OP_ERROR => {
            let msg = String::from_utf8_lossy(&body).into_owned();
            return Ok(Response::Error(msg));
        }
        OP_SHED => {
            let msg = String::from_utf8_lossy(&body).into_owned();
            return Ok(Response::Shed(msg));
        }
        other => {
            return Err(invalid(format!(
                "unknown response opcode {other:#04x}"
            )))
        }
    };
    c.finish()?;
    Ok(rs)
}

/// Read one distributed frame; `Ok(None)` = clean EOF (the peer hung
/// up between frames). ASSIGN/PARTIAL payloads are checksum-verified
/// here — a mismatch is an `Err`, and since the failed frame was
/// still fully consumed, the *stream* stays in sync; whether to keep
/// the connection is the caller's policy (the coordinator drops it:
/// bits from a corrupting peer are not worth re-trusting).
pub fn read_dist_frame(r: &mut impl Read) -> Result<Option<DistFrame>> {
    let Some(op) = read_opcode(r)? else {
        return Ok(None);
    };
    let body = read_body(r)?;
    let mut c = Cursor::new(&body);
    let f = match op {
        OP_ASSIGN => {
            let job = c.u64()?;
            let crc = c.u32()?;
            let payload = c.rest().to_vec();
            if crc32(&payload) != crc {
                return Err(invalid(format!(
                    "ASSIGN payload for job {job} fails its checksum"
                )));
            }
            DistFrame::Assign { job, payload }
        }
        OP_PARTIAL => {
            let job = c.u64()?;
            let seq = c.u32()?;
            let crc = c.u32()?;
            let payload = c.rest().to_vec();
            if crc32(&payload) != crc {
                return Err(invalid(format!(
                    "PARTIAL {seq} of job {job} fails its checksum"
                )));
            }
            DistFrame::Partial { job, seq, payload }
        }
        OP_ACK => {
            let f = DistFrame::Ack {
                job: c.u64()?,
                kind: c.u8()?,
                info: c.u64()?,
            };
            c.finish()?;
            f
        }
        OP_RETRY => {
            let f = DistFrame::Retry { job: c.u64()?, reason: c.str()? };
            c.finish()?;
            f
        }
        OP_FETCH => {
            let f = DistFrame::Fetch {
                job: c.u64()?,
                col0: c.u32()?,
                count: c.u32()?,
            };
            c.finish()?;
            f
        }
        OP_DATA => {
            let job = c.u64()?;
            let col0 = c.u32()?;
            let crc = c.u32()?;
            let payload = c.rest().to_vec();
            if crc32(&payload) != crc {
                return Err(invalid(format!(
                    "DATA block at col {col0} of job {job} fails its \
                     checksum"
                )));
            }
            DistFrame::Data { job, col0, payload }
        }
        other => {
            return Err(invalid(format!(
                "unknown distributed opcode {other:#04x}"
            )))
        }
    };
    Ok(Some(f))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_request(rq: &Request) -> Request {
        let mut buf = Vec::new();
        write_request(&mut buf, rq).unwrap();
        let mut r = &buf[..];
        let back = read_request(&mut r).unwrap().unwrap();
        assert!(r.is_empty(), "request frame fully consumed");
        back
    }

    #[test]
    fn request_frames_roundtrip() {
        match roundtrip_request(&Request::ModelInfo { model: "m".into() })
        {
            Request::ModelInfo { model } => assert_eq!(model, "m"),
            other => panic!("wrong request: {other:?}"),
        }
        let x = FeatureMatrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.])
            .unwrap();
        match roundtrip_request(&Request::Predict {
            model: String::new(),
            x: x.clone(),
        }) {
            Request::Predict { model, x: back } => {
                assert!(model.is_empty());
                assert_eq!(back.data, x.data);
                assert_eq!((back.rows, back.cols), (2, 3));
            }
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn response_frames_roundtrip() {
        let mut buf = Vec::new();
        write_response(&mut buf, &Response::Probabilities(vec![0.25, 1.0]))
            .unwrap();
        write_response(&mut buf, &Response::Error("boom".into())).unwrap();
        let mut r = &buf[..];
        match read_response(&mut r).unwrap() {
            Response::Probabilities(p) => assert_eq!(p, vec![0.25, 1.0]),
            other => panic!("wrong response: {other:?}"),
        }
        match read_response(&mut r).unwrap() {
            Response::Error(msg) => assert_eq!(msg, "boom"),
            other => panic!("wrong response: {other:?}"),
        }
        assert!(r.is_empty());
    }

    #[test]
    fn shed_frame_roundtrips() {
        let mut buf = Vec::new();
        write_response(
            &mut buf,
            &Response::Shed("at capacity".into()),
        )
        .unwrap();
        let mut r = &buf[..];
        match read_response(&mut r).unwrap() {
            Response::Shed(msg) => assert_eq!(msg, "at capacity"),
            other => panic!("wrong response: {other:?}"),
        }
        assert!(r.is_empty());
    }

    #[test]
    fn eof_between_frames_is_clean() {
        let mut r: &[u8] = &[];
        assert!(read_request(&mut r).unwrap().is_none());
    }

    #[test]
    fn malformed_frames_rejected() {
        // unknown opcode
        let mut r: &[u8] = &[9, 0, 0, 0, 0];
        assert!(read_request(&mut r).is_err());
        // truncated body
        let mut buf = Vec::new();
        write_request(
            &mut buf,
            &Request::ModelInfo { model: "x".into() },
        )
        .unwrap();
        buf.pop();
        let mut r = &buf[..];
        assert!(read_request(&mut r).is_err());
        // trailing garbage inside the body
        let mut body = Vec::new();
        put_str(&mut body, "");
        body.push(7);
        let mut buf = Vec::new();
        write_frame(&mut buf, OP_MODEL_INFO, &body).unwrap();
        let mut r = &buf[..];
        assert!(read_request(&mut r).is_err());
    }

    fn roundtrip_dist(f: &DistFrame) -> DistFrame {
        let mut buf = Vec::new();
        write_dist_frame(&mut buf, f).unwrap();
        let mut r = &buf[..];
        let back = read_dist_frame(&mut r).unwrap().unwrap();
        assert!(r.is_empty(), "dist frame fully consumed");
        back
    }

    #[test]
    fn dist_frames_roundtrip() {
        match roundtrip_dist(&DistFrame::Assign {
            job: 7,
            payload: vec![1, 2, 3],
        }) {
            DistFrame::Assign { job, payload } => {
                assert_eq!(job, 7);
                assert_eq!(payload, vec![1, 2, 3]);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match roundtrip_dist(&DistFrame::Partial {
            job: 7,
            seq: 2,
            payload: vec![9; 100],
        }) {
            DistFrame::Partial { job, seq, payload } => {
                assert_eq!((job, seq), (7, 2));
                assert_eq!(payload, vec![9; 100]);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match roundtrip_dist(&DistFrame::Ack {
            job: u64::MAX,
            kind: ACK_HELLO,
            info: 4242,
        }) {
            DistFrame::Ack { job, kind, info } => {
                assert_eq!(job, u64::MAX);
                assert_eq!(kind, ACK_HELLO);
                assert_eq!(info, 4242);
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match roundtrip_dist(&DistFrame::Retry {
            job: 3,
            reason: "no such file".into(),
        }) {
            DistFrame::Retry { job, reason } => {
                assert_eq!(job, 3);
                assert_eq!(reason, "no such file");
            }
            other => panic!("wrong frame: {other:?}"),
        }
        // empty payloads are legal (checksum of zero bytes)
        match roundtrip_dist(&DistFrame::Assign {
            job: 0,
            payload: Vec::new(),
        }) {
            DistFrame::Assign { payload, .. } => assert!(payload.is_empty()),
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn range_serving_frames_roundtrip() {
        match roundtrip_dist(&DistFrame::Fetch {
            job: 11,
            col0: 32,
            count: 8,
        }) {
            DistFrame::Fetch { job, col0, count } => {
                assert_eq!((job, col0, count), (11, 32, 8));
            }
            other => panic!("wrong frame: {other:?}"),
        }
        match roundtrip_dist(&DistFrame::Data {
            job: 11,
            col0: 32,
            payload: vec![0xAB; 64],
        }) {
            DistFrame::Data { job, col0, payload } => {
                assert_eq!((job, col0), (11, 32));
                assert_eq!(payload, vec![0xAB; 64]);
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn corrupted_data_block_rejected() {
        let mut buf = Vec::new();
        write_dist_frame(
            &mut buf,
            &DistFrame::Data { job: 4, col0: 0, payload: vec![7; 48] },
        )
        .unwrap();
        let last = buf.len() - 1; // inside the payload
        buf[last] ^= 0x01;
        let mut r = &buf[..];
        let err = read_dist_frame(&mut r).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // the frame was still fully consumed (stream stays framed)
        assert!(r.is_empty());
    }

    #[test]
    fn corrupted_dist_payload_rejected() {
        let mut buf = Vec::new();
        write_dist_frame(
            &mut buf,
            &DistFrame::Partial { job: 1, seq: 0, payload: vec![5; 32] },
        )
        .unwrap();
        let last = buf.len() - 1; // inside the payload
        buf[last] ^= 0xFF;
        let mut r = &buf[..];
        let err = read_dist_frame(&mut r).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // and the frame was still fully consumed (stream stays framed)
        assert!(r.is_empty());
    }

    #[test]
    fn oversized_length_claim_fails_without_huge_alloc() {
        // header claims a body of MAX_BODY_BYTES but provides 3 bytes;
        // the capped incremental read must error out at EOF instead of
        // zero-filling a quarter-gigabyte buffer first
        let mut buf = vec![OP_ACK];
        buf.extend_from_slice(&(MAX_BODY_BYTES as u32).to_le_bytes());
        buf.extend_from_slice(&[1, 2, 3]);
        let mut r = &buf[..];
        let err = read_dist_frame(&mut r).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        // one past the limit is rejected before reading anything
        let mut buf = vec![OP_ACK];
        buf.extend_from_slice(
            &((MAX_BODY_BYTES + 1) as u32).to_le_bytes(),
        );
        let mut r = &buf[..];
        let err = read_dist_frame(&mut r).unwrap_err();
        assert!(err.to_string().contains("exceeds limit"), "{err}");
    }
}
