//! An LRU cache of loaded `.fcm` models — the piece that lets one
//! resident model answer every concurrent client instead of each
//! connection deserializing its own copy (ADR-004 §Serving).
//!
//! Deserialization happens *outside* the cache lock, so a cold load
//! of one model never stalls requests hitting already-resident
//! models. The trade-off: concurrent cold misses on the *same* model
//! may each deserialize it (first insert wins, later copies are
//! dropped) — wasted work bounded by the number of simultaneous
//! requesters, which beats freezing all traffic for the duration of
//! a load.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::error::Result;
use crate::model::{load_model, FittedModel};

struct Entry {
    model: Arc<FittedModel>,
    last_used: u64,
}

struct CacheState {
    map: HashMap<PathBuf, Entry>,
    clock: u64,
    loads: u64,
    hits: u64,
}

/// Bounded LRU cache of deserialized models, keyed by path.
pub struct ModelCache {
    capacity: usize,
    state: Mutex<CacheState>,
}

impl ModelCache {
    /// Create with room for `capacity` resident models (min 1).
    pub fn new(capacity: usize) -> Self {
        ModelCache {
            capacity: capacity.max(1),
            state: Mutex::new(CacheState {
                map: HashMap::new(),
                clock: 0,
                loads: 0,
                hits: 0,
            }),
        }
    }

    /// Resident model count.
    pub fn len(&self) -> usize {
        self.state.lock().expect("cache poisoned").map.len()
    }

    /// Whether the cache holds nothing.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Disk deserializations performed so far (hit-rate accounting).
    pub fn loads(&self) -> u64 {
        self.state.lock().expect("cache poisoned").loads
    }

    /// Lookups served from a resident model (the `GET /metrics`
    /// `cache_hits` field).
    pub fn hits(&self) -> u64 {
        self.state.lock().expect("cache poisoned").hits
    }

    /// Fetch a model, deserializing and inserting it on miss; the
    /// least-recently-used entry is evicted when the cache is full.
    /// The disk load runs without holding the cache lock (see the
    /// module docs for the dogpile trade-off).
    pub fn get_or_load(&self, path: &Path) -> Result<Arc<FittedModel>> {
        {
            let mut st = self.state.lock().expect("cache poisoned");
            st.clock += 1;
            let stamp = st.clock;
            if let Some(e) = st.map.get_mut(path) {
                e.last_used = stamp;
                let model = e.model.clone();
                st.hits += 1;
                return Ok(model);
            }
        }
        // cold miss: deserialize with the lock released so requests
        // against resident models keep flowing
        let model = Arc::new(load_model(path)?);
        let mut st = self.state.lock().expect("cache poisoned");
        st.loads += 1;
        st.clock += 1;
        let stamp = st.clock;
        if let Some(e) = st.map.get_mut(path) {
            // a concurrent requester loaded it first: keep theirs so
            // every caller shares one resident copy
            e.last_used = stamp;
            let found = e.model.clone();
            st.hits += 1;
            return Ok(found);
        }
        if st.map.len() >= self.capacity {
            if let Some(oldest) = st
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            {
                st.map.remove(&oldest);
            }
        }
        st.map.insert(
            path.to_path_buf(),
            Entry { model: model.clone(), last_used: stamp },
        );
        Ok(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{
        DataConfig, EstimatorConfig, Method, ReduceConfig,
    };
    use crate::model::{fit_model, save_model, FitOptions};
    use crate::volume::MorphometryGenerator;

    /// Fit + save a tiny model under a unique stem; returns the path.
    fn saved_model(tag: &str, seed: u64) -> PathBuf {
        let dc = DataConfig {
            dims: [8, 9, 7],
            n_samples: 24,
            seed,
            ..Default::default()
        };
        let (ds, y) =
            MorphometryGenerator::new(dc.dims).generate(dc.n_samples, seed);
        let reduce = ReduceConfig {
            method: Method::Fast,
            ratio: 10,
            ..Default::default()
        };
        let est = EstimatorConfig {
            cv_folds: 3,
            max_iter: 60,
            ..Default::default()
        };
        let model = fit_model(
            &ds,
            &y,
            &reduce,
            &est,
            &dc,
            &FitOptions::default(),
        )
        .unwrap();
        let dir = std::env::temp_dir().join("fastclust_cache_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{tag}.fcm"));
        save_model(&path, &model).unwrap();
        path
    }

    #[test]
    fn hit_shares_the_same_arc() {
        let path = saved_model("hit", 1);
        let cache = ModelCache::new(2);
        let a = cache.get_or_load(&path).unwrap();
        let b = cache.get_or_load(&path).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second get must be a cache hit");
        assert_eq!(cache.loads(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let p1 = saved_model("lru1", 1);
        let p2 = saved_model("lru2", 2);
        let p3 = saved_model("lru3", 3);
        let cache = ModelCache::new(2);
        cache.get_or_load(&p1).unwrap();
        cache.get_or_load(&p2).unwrap();
        cache.get_or_load(&p1).unwrap(); // p1 now most recent
        cache.get_or_load(&p3).unwrap(); // evicts p2
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.loads(), 3);
        cache.get_or_load(&p1).unwrap(); // still resident
        assert_eq!(cache.loads(), 3);
        cache.get_or_load(&p2).unwrap(); // reload after eviction
        assert_eq!(cache.loads(), 4);
    }

    #[test]
    fn missing_file_is_an_error_not_a_panic() {
        let cache = ModelCache::new(1);
        assert!(cache
            .get_or_load(Path::new("/nonexistent/m.fcm"))
            .is_err());
        assert!(cache.is_empty());
    }
}
