//! The concurrent decode server (ADR-004): a long-lived loopback TCP
//! service that keeps fitted `.fcm` models resident and answers
//! compress / predict / model-info requests against them — the first
//! step from "reproduction script" to "system that answers requests"
//! on the ROADMAP's path to heavy-traffic serving.
//!
//! # Pieces
//!
//! * [`protocol`] — the length-prefixed binary wire format;
//! * [`ModelCache`] — LRU of deserialized models shared across
//!   connections via `Arc`;
//! * [`Server`] / [`ServerHandle`] — accept loop, per-connection
//!   request batching onto the shared
//!   [`crate::coordinator::WorkerPool`], orderly shutdown;
//! * [`ServeClient`] — a blocking client (CLI, tests, reference).
//!
//! # Guarantees
//!
//! * **Bit-equivalence**: a served `predict`/`compress` response is
//!   byte-identical to the offline apply-only path on the same model
//!   ([`crate::model::FittedModel::predict_proba`] /
//!   [`crate::model::FittedModel::compress`]) — asserted by the
//!   `serve_smoke` integration suite under ≥8 concurrent clients.
//! * **Order**: responses on a connection arrive in request order,
//!   so clients may pipeline.
//! * **Clean teardown**: [`ServerHandle::shutdown`] joins every
//!   thread (connections, accept, pool workers) before returning.

mod cache;
mod client;
pub mod protocol;
mod server;

pub use cache::ModelCache;
pub use client::ServeClient;
pub use protocol::{Request, Response};
pub use server::{
    ServeLog, ServeOptions, ServeStats, Server, ServerHandle,
};
