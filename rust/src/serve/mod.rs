//! The event-driven decode server (ADR-007, which supersedes the
//! thread-per-connection design of ADR-004): a long-lived loopback
//! TCP service that keeps fitted `.fcm` models resident and answers
//! compress / predict / model-info requests against them — over a
//! length-prefixed binary protocol and, optionally, an HTTP/JSON
//! gateway — from a single readiness-driven event loop.
//!
//! # Pieces
//!
//! * [`event_loop`] — the readiness layer: epoll on Linux, poll(2)
//!   on other unix, all through raw `extern "C"` declarations
//!   (ADR-001: no external crates);
//! * [`protocol`] — the length-prefixed binary wire format;
//! * [`http`] — the bounded HTTP/1.1 subset the gateway speaks;
//! * [`ModelRegistry`] — the multi-model fleet (ADR-008): lazily
//!   mapped `.fcm` models shared across connections via `Arc`,
//!   evicted by resident bytes, hot-reloaded on file change;
//! * [`Server`] / [`ServerHandle`] — nonblocking accept with an
//!   explicit connection budget (over-budget accepts are *shed* with
//!   a binary shed frame / HTTP 429, never silently dropped),
//!   cross-connection micro-batching of same-model requests onto the
//!   shared [`crate::coordinator::WorkerPool`], `GET /metrics`
//!   observability, orderly shutdown;
//! * [`ServeClient`] — a blocking client (CLI, tests, reference)
//!   with bounded connect retry.
//!
//! # Guarantees
//!
//! * **Bit-equivalence**: a served `predict`/`compress` response is
//!   byte-identical to the offline apply-only path on the same model
//!   ([`crate::model::FittedModel::predict_proba`] /
//!   [`crate::model::FittedModel::compress`]) — batched or not,
//!   binary or HTTP/JSON — asserted by the `serve_smoke` and
//!   `serve_batching` integration suites under concurrent clients.
//! * **Order**: responses on a connection arrive in request order
//!   even when neighboring requests land in different batches, so
//!   clients may pipeline.
//! * **Clean teardown**: [`ServerHandle::shutdown`] joins every
//!   thread (the event loop and the pool workers) before returning.
//!
//! # Operational hardening (ADR-010)
//!
//! The gateway answers `GET /healthz` (liveness) and `GET /readyz`
//! (readiness: 503 while draining or when the default model stops
//! resolving); [`ServerHandle::install_sigterm`] routes SIGTERM to a
//! graceful drain-and-exit; and `--idle-timeout-ms` arms a
//! per-connection idle deadline so a slow-loris peer cannot pin the
//! connection budget. All of it is exercised under seeded network
//! faults by the `serve_chaos` integration suite via
//! [`crate::testkit::ChaosProxy`].

mod batch;
mod client;
pub mod event_loop;
pub mod http;
mod metrics;
pub mod protocol;
mod registry;
mod server;

pub use registry::ModelRegistry;
pub use client::ServeClient;
pub use metrics::Metrics;
pub use protocol::{Request, Response};
pub use server::{
    sigterm_requested, ServeLog, ServeOptions, ServeStats, Server,
    ServerHandle,
};
