//! Readiness primitives for the event-driven server (ADR-007): a
//! [`Poller`] that multiplexes many nonblocking sockets on one
//! thread, and a [`WakePipe`] that lets worker threads interrupt a
//! blocked wait.
//!
//! ADR-001 forbids external crates, so the backends are raw
//! `extern "C"` declarations against the system libc that `std`
//! already links:
//!
//! * **epoll** (Linux, level-triggered) — the default on Linux;
//! * **poll(2)** (any unix) — the portable fallback, also selectable
//!   on Linux via `FASTCLUST_SERVE_BACKEND=poll` (mirrors the
//!   `FASTCLUST_KERNEL_BACKEND` escape hatch of ADR-005);
//! * a **tick shim** (non-unix) — no readiness syscall at all: every
//!   registered token reports ready on a short sleep tick, and the
//!   nonblocking sockets turn spurious readiness into `WouldBlock`.
//!   Functionally correct, never fast; unix hosts never use it.
//!
//! Level-triggered semantics everywhere: a fd with unread input (or
//! writable space while write interest is registered) reports ready
//! on every wait, so the loop may process as little or as much per
//! event as it likes without losing wakeups.

use crate::error::Result;

/// Caller-chosen identifier attached to a registered fd and echoed
/// in every [`Event`] for it. The server uses monotonically
/// increasing tokens so a completion for a dead connection can never
/// alias a live one.
pub type Token = usize;

/// Raw file descriptor (`c_int` on unix; a dummy on other hosts so
/// the crate still compiles there).
pub type Fd = i32;

/// The raw fd of a socket, for [`Poller`] registration.
#[cfg(unix)]
pub fn sys_fd<T: std::os::unix::io::AsRawFd>(t: &T) -> Fd {
    t.as_raw_fd()
}

/// Non-unix hosts have no raw fds; the tick-shim poller ignores them.
#[cfg(not(unix))]
pub fn sys_fd<T>(_t: &T) -> Fd {
    -1
}

/// What a registered fd should report.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest {
    /// Report when the fd has readable input (or a pending accept).
    pub read: bool,
    /// Report when the fd can take more output.
    pub write: bool,
}

impl Interest {
    /// Read-only interest (the common idle-connection state).
    pub const READ: Interest = Interest { read: true, write: false };
    /// Read + write (a connection with buffered output).
    pub const BOTH: Interest = Interest { read: true, write: true };
    /// Neither: keep the registration (hangup still reports) but ask
    /// for no data events — a connection draining in-flight work.
    pub const NONE: Interest = Interest { read: false, write: false };
}

/// One readiness report.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Token the fd was registered with.
    pub token: Token,
    /// Input (or a pending accept) is available.
    pub readable: bool,
    /// Output space is available.
    pub writable: bool,
    /// Peer hung up or the fd errored; the owner should read to EOF
    /// and drop the connection.
    pub hangup: bool,
}

/// Readiness multiplexer over one of the compiled backends.
pub struct Poller {
    backend: Backend,
}

enum Backend {
    #[cfg(target_os = "linux")]
    Epoll(epoll::Epoll),
    #[cfg(unix)]
    Poll(poll::Poll),
    #[cfg(not(unix))]
    Tick(tick::Tick),
}

impl Poller {
    /// Open the platform's best backend. On Linux the
    /// `FASTCLUST_SERVE_BACKEND=poll` environment variable forces
    /// the portable poll(2) path (the escape hatch CI exercises).
    pub fn new() -> Result<Poller> {
        #[cfg(target_os = "linux")]
        {
            let forced = std::env::var("FASTCLUST_SERVE_BACKEND")
                .map(|v| v.eq_ignore_ascii_case("poll"))
                .unwrap_or(false);
            if !forced {
                return Ok(Poller {
                    backend: Backend::Epoll(epoll::Epoll::new()?),
                });
            }
        }
        #[cfg(unix)]
        {
            Ok(Poller { backend: Backend::Poll(poll::Poll::new()) })
        }
        #[cfg(not(unix))]
        {
            Ok(Poller { backend: Backend::Tick(tick::Tick::new()) })
        }
    }

    /// Name of the live backend (logged at server start).
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(_) => "epoll",
            #[cfg(unix)]
            Backend::Poll(_) => "poll",
            #[cfg(not(unix))]
            Backend::Tick(_) => "tick",
        }
    }

    /// Register `fd` under `token` with an initial interest.
    pub fn add(
        &mut self,
        fd: Fd,
        token: Token,
        interest: Interest,
    ) -> Result<()> {
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(e) => e.add(fd, token, interest),
            #[cfg(unix)]
            Backend::Poll(p) => {
                p.add(fd, token, interest);
                Ok(())
            }
            #[cfg(not(unix))]
            Backend::Tick(t) => {
                t.add(token, interest);
                Ok(())
            }
        }
    }

    /// Change the interest of an already-registered fd.
    pub fn modify(
        &mut self,
        fd: Fd,
        token: Token,
        interest: Interest,
    ) -> Result<()> {
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(e) => e.modify(fd, token, interest),
            #[cfg(unix)]
            Backend::Poll(p) => {
                p.modify(token, interest);
                Ok(())
            }
            #[cfg(not(unix))]
            Backend::Tick(t) => {
                t.modify(token, interest);
                Ok(())
            }
        }
    }

    /// Deregister an fd. Must run **before** the fd is closed, or a
    /// recycled descriptor could inherit the stale registration.
    pub fn remove(&mut self, fd: Fd, token: Token) -> Result<()> {
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(e) => e.remove(fd),
            #[cfg(unix)]
            Backend::Poll(p) => {
                p.remove(token);
                Ok(())
            }
            #[cfg(not(unix))]
            Backend::Tick(t) => {
                t.remove(token);
                Ok(())
            }
        }
    }

    /// Block until readiness or `timeout_ms` (0 = just poll, never
    /// sleep), filling `out` with the ready set (cleared first). A
    /// timeout is an empty `out`, not an error — that emptiness is
    /// the quiescence signal the server's batch flush keys on.
    pub fn wait(
        &mut self,
        out: &mut Vec<Event>,
        timeout_ms: i32,
    ) -> Result<()> {
        out.clear();
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(e) => e.wait(out, timeout_ms),
            #[cfg(unix)]
            Backend::Poll(p) => p.wait(out, timeout_ms),
            #[cfg(not(unix))]
            Backend::Tick(t) => {
                t.wait(out, timeout_ms);
                Ok(())
            }
        }
    }
}

/// A self-pipe that wakes a blocked [`Poller::wait`] from another
/// thread: register [`WakePipe::fd`] for read interest, hand
/// [`Waker`] clones to the threads that need to interrupt the loop,
/// and [`WakePipe::drain`] when the token reports readable.
///
/// The write end lives behind an `Arc` shared by every `Waker`, so
/// it stays open (and its descriptor number stays unrecycled) until
/// the last worker drops its handle — a wake can race shutdown but
/// can never scribble on an unrelated fd.
pub struct WakePipe {
    #[cfg(unix)]
    read_fd: Fd,
    #[cfg(unix)]
    write: std::sync::Arc<sys::OwnedFd>,
}

/// Cloneable wake handle ([`WakePipe::waker`]).
#[derive(Clone)]
pub struct Waker {
    #[cfg(unix)]
    write: std::sync::Arc<sys::OwnedFd>,
}

#[cfg(unix)]
impl WakePipe {
    /// Open the pipe pair; both ends are switched to nonblocking so
    /// neither a wake burst nor a drain can stall a thread.
    pub fn new() -> Result<WakePipe> {
        let (r, w) = sys::pipe_nonblocking()?;
        Ok(WakePipe {
            read_fd: r,
            write: std::sync::Arc::new(sys::OwnedFd(w)),
        })
    }

    /// The read end, for poller registration.
    pub fn fd(&self) -> Fd {
        self.read_fd
    }

    /// A wake handle for another thread.
    pub fn waker(&self) -> Waker {
        Waker { write: self.write.clone() }
    }

    /// Consume every queued wake byte (nonblocking).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        while sys::read_fd(self.read_fd, &mut buf) > 0 {}
    }
}

#[cfg(unix)]
impl Drop for WakePipe {
    fn drop(&mut self) {
        sys::close_fd(self.read_fd);
    }
}

#[cfg(unix)]
impl Waker {
    /// Queue one wake byte. Best-effort: a full pipe already wakes
    /// the loop, and a closed read end (loop already gone) is fine.
    pub fn wake(&self) {
        let _ = sys::write_fd(self.write.0, &[1u8]);
    }

    /// Raw write-end descriptor, for contexts that must wake the
    /// loop with nothing but async-signal-safe calls (the SIGTERM
    /// handler: one `write(2)`, no allocation, no locks). The fd
    /// stays valid while any `Waker` clone is alive.
    pub fn raw_fd(&self) -> Fd {
        self.write.0
    }
}

#[cfg(not(unix))]
impl WakePipe {
    /// Non-unix shim: the tick poller wakes itself every few
    /// milliseconds, so there is nothing to open.
    pub fn new() -> Result<WakePipe> {
        Ok(WakePipe {})
    }

    /// No fd to register on this host.
    pub fn fd(&self) -> Fd {
        -1
    }

    /// A no-op wake handle.
    pub fn waker(&self) -> Waker {
        Waker {}
    }

    /// Nothing queues on this host.
    pub fn drain(&self) {}
}

#[cfg(not(unix))]
impl Waker {
    /// No-op: the tick poller's sleep bound is the wake latency.
    pub fn wake(&self) {}

    /// No raw fd on this host.
    pub fn raw_fd(&self) -> Fd {
        -1
    }
}

#[cfg(unix)]
mod sys {
    //! Raw libc declarations shared by the unix backends.

    use super::Fd;
    use crate::error::Result;
    use std::os::raw::{c_int, c_void};

    extern "C" {
        fn pipe(fds: *mut c_int) -> c_int;
        fn fcntl(fd: c_int, cmd: c_int, ...) -> c_int;
        fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
        fn close(fd: c_int) -> c_int;
    }

    const F_GETFL: c_int = 3;
    const F_SETFL: c_int = 4;
    #[cfg(target_os = "linux")]
    const O_NONBLOCK: c_int = 0o4000;
    #[cfg(not(target_os = "linux"))]
    const O_NONBLOCK: c_int = 0x0004;

    /// An fd closed on drop (the wake pipe's shared write end).
    pub(super) struct OwnedFd(pub(super) Fd);

    impl Drop for OwnedFd {
        fn drop(&mut self) {
            close_fd(self.0);
        }
    }

    // Safety: an fd is just an index into the kernel's table; the
    // Arc around OwnedFd serializes nothing because write(2) on a
    // pipe is atomic for these single-byte payloads.
    unsafe impl Send for OwnedFd {}
    unsafe impl Sync for OwnedFd {}

    pub(super) fn pipe_nonblocking() -> Result<(Fd, Fd)> {
        let mut fds = [0 as c_int; 2];
        if unsafe { pipe(fds.as_mut_ptr()) } != 0 {
            return Err(std::io::Error::last_os_error().into());
        }
        for fd in fds {
            let flags = unsafe { fcntl(fd, F_GETFL, 0) };
            if flags < 0
                || unsafe { fcntl(fd, F_SETFL, flags | O_NONBLOCK) }
                    < 0
            {
                let e = std::io::Error::last_os_error();
                close_fd(fds[0]);
                close_fd(fds[1]);
                return Err(e.into());
            }
        }
        Ok((fds[0], fds[1]))
    }

    pub(super) fn read_fd(fd: Fd, buf: &mut [u8]) -> isize {
        unsafe {
            read(fd, buf.as_mut_ptr() as *mut c_void, buf.len())
        }
    }

    pub(super) fn write_fd(fd: Fd, buf: &[u8]) -> isize {
        unsafe {
            write(fd, buf.as_ptr() as *const c_void, buf.len())
        }
    }

    pub(super) fn close_fd(fd: Fd) {
        unsafe {
            close(fd);
        }
    }
}

#[cfg(target_os = "linux")]
mod epoll {
    //! The Linux epoll backend (level-triggered).

    use super::{Event, Fd, Interest, Token};
    use crate::error::Result;
    use std::os::raw::c_int;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLL_CLOEXEC: c_int = 0o2000000;

    // The kernel ABI packs this struct on x86_64 only.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(
            epfd: c_int,
            op: c_int,
            fd: c_int,
            event: *mut EpollEvent,
        ) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
    }

    fn mask(interest: Interest) -> u32 {
        let mut m = 0;
        if interest.read {
            m |= EPOLLIN;
        }
        if interest.write {
            m |= EPOLLOUT;
        }
        m
    }

    pub(super) struct Epoll {
        epfd: Fd,
        buf: Vec<EpollEvent>,
    }

    impl Epoll {
        pub(super) fn new() -> Result<Epoll> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(std::io::Error::last_os_error().into());
            }
            Ok(Epoll {
                epfd,
                buf: vec![EpollEvent { events: 0, data: 0 }; 128],
            })
        }

        fn ctl(
            &mut self,
            op: c_int,
            fd: Fd,
            events: u32,
            token: Token,
        ) -> Result<()> {
            let mut ev =
                EpollEvent { events, data: token as u64 };
            let rc =
                unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc != 0 {
                return Err(std::io::Error::last_os_error().into());
            }
            Ok(())
        }

        pub(super) fn add(
            &mut self,
            fd: Fd,
            token: Token,
            interest: Interest,
        ) -> Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, mask(interest), token)
        }

        pub(super) fn modify(
            &mut self,
            fd: Fd,
            token: Token,
            interest: Interest,
        ) -> Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, mask(interest), token)
        }

        pub(super) fn remove(&mut self, fd: Fd) -> Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
        }

        pub(super) fn wait(
            &mut self,
            out: &mut Vec<Event>,
            timeout_ms: i32,
        ) -> Result<()> {
            let n = unsafe {
                epoll_wait(
                    self.epfd,
                    self.buf.as_mut_ptr(),
                    self.buf.len() as c_int,
                    timeout_ms,
                )
            };
            if n < 0 {
                let e = std::io::Error::last_os_error();
                if e.kind() == std::io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e.into());
            }
            for ev in &self.buf[..n as usize] {
                // copy out of the (possibly packed) struct before use
                let bits = ev.events;
                let data = ev.data;
                out.push(Event {
                    token: data as Token,
                    readable: bits & EPOLLIN != 0,
                    writable: bits & EPOLLOUT != 0,
                    hangup: bits & (EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Epoll {
        fn drop(&mut self) {
            super::sys::close_fd(self.epfd);
        }
    }
}

#[cfg(unix)]
mod poll {
    //! The portable poll(2) backend: the interest set lives in a
    //! plain vector and the pollfd array is rebuilt per wait —
    //! O(fds) per call, which is fine at the server's bounded
    //! connection budget.

    use super::{Event, Fd, Interest, Token};
    use crate::error::Result;
    use std::os::raw::{c_int, c_short};

    const POLLIN: c_short = 0x001;
    const POLLOUT: c_short = 0x004;
    const POLLERR: c_short = 0x008;
    const POLLHUP: c_short = 0x010;
    const POLLNVAL: c_short = 0x020;

    #[repr(C)]
    struct PollFd {
        fd: c_int,
        events: c_short,
        revents: c_short,
    }

    #[cfg(target_os = "linux")]
    type NFds = u64;
    #[cfg(not(target_os = "linux"))]
    type NFds = u32;

    extern "C" {
        fn poll(
            fds: *mut PollFd,
            nfds: NFds,
            timeout: c_int,
        ) -> c_int;
    }

    pub(super) struct Poll {
        regs: Vec<(Fd, Token, Interest)>,
    }

    impl Poll {
        pub(super) fn new() -> Poll {
            Poll { regs: Vec::new() }
        }

        pub(super) fn add(
            &mut self,
            fd: Fd,
            token: Token,
            interest: Interest,
        ) {
            self.regs.push((fd, token, interest));
        }

        pub(super) fn modify(
            &mut self,
            token: Token,
            interest: Interest,
        ) {
            if let Some(r) =
                self.regs.iter_mut().find(|(_, t, _)| *t == token)
            {
                r.2 = interest;
            }
        }

        pub(super) fn remove(&mut self, token: Token) {
            self.regs.retain(|(_, t, _)| *t != token);
        }

        pub(super) fn wait(
            &mut self,
            out: &mut Vec<Event>,
            timeout_ms: i32,
        ) -> Result<()> {
            let mut fds: Vec<PollFd> = self
                .regs
                .iter()
                .map(|&(fd, _, i)| {
                    let mut events = 0;
                    if i.read {
                        events |= POLLIN;
                    }
                    if i.write {
                        events |= POLLOUT;
                    }
                    PollFd { fd, events, revents: 0 }
                })
                .collect();
            let n = unsafe {
                poll(
                    fds.as_mut_ptr(),
                    fds.len() as NFds,
                    timeout_ms,
                )
            };
            if n < 0 {
                let e = std::io::Error::last_os_error();
                if e.kind() == std::io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e.into());
            }
            for (pfd, &(_, token, _)) in
                fds.iter().zip(self.regs.iter())
            {
                let r = pfd.revents;
                if r != 0 {
                    out.push(Event {
                        token,
                        readable: r & POLLIN != 0,
                        writable: r & POLLOUT != 0,
                        hangup: r & (POLLERR | POLLHUP | POLLNVAL)
                            != 0,
                    });
                }
            }
            Ok(())
        }
    }
}

#[cfg(not(unix))]
mod tick {
    //! Portability shim for hosts without a readiness syscall we can
    //! reach dependency-free: sleep a short tick, then report every
    //! registered token as ready and let the nonblocking sockets
    //! answer `WouldBlock` for the idle ones.

    use super::{Event, Interest, Token};
    use std::time::Duration;

    const TICK_MS: u64 = 5;

    pub(super) struct Tick {
        regs: Vec<(Token, Interest)>,
    }

    impl Tick {
        pub(super) fn new() -> Tick {
            Tick { regs: Vec::new() }
        }

        pub(super) fn add(
            &mut self,
            token: Token,
            interest: Interest,
        ) {
            self.regs.push((token, interest));
        }

        pub(super) fn modify(
            &mut self,
            token: Token,
            interest: Interest,
        ) {
            if let Some(r) =
                self.regs.iter_mut().find(|(t, _)| *t == token)
            {
                r.1 = interest;
            }
        }

        pub(super) fn remove(&mut self, token: Token) {
            self.regs.retain(|(t, _)| *t != token);
        }

        pub(super) fn wait(
            &mut self,
            out: &mut Vec<Event>,
            timeout_ms: i32,
        ) {
            if timeout_ms != 0 {
                let ms = (timeout_ms.max(0) as u64).min(TICK_MS);
                std::thread::sleep(Duration::from_millis(ms));
            }
            for &(token, i) in &self.regs {
                if i.read || i.write {
                    out.push(Event {
                        token,
                        readable: i.read,
                        writable: i.write,
                        hangup: false,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{Ipv4Addr, TcpListener, TcpStream};

    #[test]
    fn poller_sees_listener_and_stream_readiness() {
        let mut p = Poller::new().unwrap();
        let listener =
            TcpListener::bind((Ipv4Addr::LOCALHOST, 0)).unwrap();
        listener.set_nonblocking(true).unwrap();
        p.add(sys_fd(&listener), 7, Interest::READ).unwrap();
        let mut events = Vec::new();
        // idle: a zero-timeout wait reports nothing (the non-unix
        // tick shim is deliberately spurious, so unix-only)
        p.wait(&mut events, 0).unwrap();
        #[cfg(unix)]
        assert!(events.iter().all(|e| e.token != 7));
        // a pending connect flips the listener readable
        let mut client =
            TcpStream::connect(listener.local_addr().unwrap())
                .unwrap();
        p.wait(&mut events, 1000).unwrap();
        assert!(
            events.iter().any(|e| e.token == 7 && e.readable),
            "listener never reported the pending accept"
        );
        let (mut srv, _) = listener.accept().unwrap();
        srv.set_nonblocking(true).unwrap();
        p.add(sys_fd(&srv), 8, Interest::READ).unwrap();
        client.write_all(b"hi").unwrap();
        p.wait(&mut events, 1000).unwrap();
        assert!(
            events.iter().any(|e| e.token == 8 && e.readable),
            "stream never reported readable input"
        );
        let mut buf = [0u8; 8];
        let n = srv.read(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"hi");
        p.remove(sys_fd(&srv), 8).unwrap();
        p.remove(sys_fd(&listener), 7).unwrap();
    }

    #[test]
    fn write_interest_reports_on_an_open_stream() {
        let mut p = Poller::new().unwrap();
        let listener =
            TcpListener::bind((Ipv4Addr::LOCALHOST, 0)).unwrap();
        let client =
            TcpStream::connect(listener.local_addr().unwrap())
                .unwrap();
        client.set_nonblocking(true).unwrap();
        p.add(sys_fd(&client), 3, Interest::BOTH).unwrap();
        let mut events = Vec::new();
        p.wait(&mut events, 1000).unwrap();
        assert!(
            events.iter().any(|e| e.token == 3 && e.writable),
            "fresh stream must be writable"
        );
        // interest NONE silences data events
        p.modify(sys_fd(&client), 3, Interest::NONE).unwrap();
        p.wait(&mut events, 0).unwrap();
        assert!(events
            .iter()
            .all(|e| e.token != 3 || (!e.readable && !e.writable)));
    }

    #[test]
    fn wake_pipe_interrupts_a_long_wait() {
        let mut p = Poller::new().unwrap();
        let wake = WakePipe::new().unwrap();
        if wake.fd() >= 0 {
            p.add(wake.fd(), 0, Interest::READ).unwrap();
        }
        let waker = wake.waker();
        let t = std::thread::spawn(move || {
            std::thread::sleep(
                std::time::Duration::from_millis(30),
            );
            waker.wake();
        });
        let t0 = std::time::Instant::now();
        let mut events = Vec::new();
        p.wait(&mut events, 5_000).unwrap();
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(4),
            "wake did not interrupt the wait"
        );
        wake.drain();
        t.join().unwrap();
    }
}
