//! A deliberately small HTTP/1.1 front-end for the decode server
//! (ADR-007 §HTTP gateway): request parsing out of a connection
//! buffer and response encoding, nothing else. The supported subset:
//!
//! * `GET` and `POST` with `Content-Length` bodies (no chunked
//!   transfer, no trailers, no 100-continue);
//! * keep-alive (HTTP/1.1 default; `Connection: close` honored;
//!   HTTP/1.0 closes unless `Connection: keep-alive`);
//! * bounded everything: request line + headers ≤ 8 KiB, bodies
//!   ≤ 64 MiB — hostile `Content-Length` claims fail before any
//!   buffering, which `protocol_fuzz` exercises.
//!
//! Routing and JSON bodies live in the server; this module owns the
//! wire syntax only, so every parse path is reachable from the fuzz
//! suite with no server running. (`GET /metrics` responses carry the
//! ADR-008 registry breakdown — residency, hits, reloads — but that
//! is assembled in the server; nothing here is model-aware.)

/// Request line + headers must fit in this many bytes.
pub const MAX_HEAD_BYTES: usize = 8 * 1024;

/// Largest accepted `Content-Length`.
pub const MAX_HTTP_BODY_BYTES: usize = 1 << 26;

/// One parsed request (body bytes are copied out so the caller can
/// drain its read buffer by `consumed`).
#[derive(Clone, Debug)]
pub struct HttpRequest {
    /// Request method, uppercased by the client (`GET` / `POST`).
    pub method: String,
    /// Request target as sent (no query parsing; the server routes
    /// on exact paths).
    pub path: String,
    /// Whether the connection survives this exchange.
    pub keep_alive: bool,
    /// The `Content-Length` body (empty when the header is absent).
    pub body: Vec<u8>,
    /// Total bytes of the request (head + body) to drain.
    pub consumed: usize,
}

/// Outcome of scanning a connection buffer for one request.
#[derive(Debug)]
pub enum Parse {
    /// The buffer holds a prefix of a valid request; read more.
    Incomplete,
    /// A complete request.
    Ok(HttpRequest),
    /// Unrecoverable request error: answer with `status` and close.
    Bad {
        /// HTTP status to send (400 / 413 / 431 / 501).
        status: u16,
        /// Human-readable cause for the JSON error body.
        msg: String,
    },
}

fn bad(status: u16, msg: impl Into<String>) -> Parse {
    Parse::Bad { status, msg: msg.into() }
}

/// Try to parse one request from the front of `buf`.
pub fn parse_request(buf: &[u8]) -> Parse {
    let head_end = match find_head_end(buf) {
        Some(e) => e,
        None if buf.len() > MAX_HEAD_BYTES => {
            return bad(431, "request head exceeds 8 KiB");
        }
        None => return Parse::Incomplete,
    };
    if head_end > MAX_HEAD_BYTES {
        return bad(431, "request head exceeds 8 KiB");
    }
    let head = match std::str::from_utf8(&buf[..head_end]) {
        Ok(h) => h,
        Err(_) => return bad(400, "request head is not UTF-8"),
    };
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, path, version) = match (
        parts.next(),
        parts.next(),
        parts.next(),
        parts.next(),
    ) {
        (Some(m), Some(p), Some(v), None)
            if !m.is_empty() && p.starts_with('/') =>
        {
            (m, p, v)
        }
        _ => return bad(400, "malformed request line"),
    };
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return bad(400, "unsupported HTTP version"),
    };
    let mut content_length: Option<usize> = None;
    let mut keep_alive = http11;
    for line in lines {
        if line.is_empty() {
            continue; // the blank terminator line
        }
        let Some((name, value)) = line.split_once(':') else {
            return bad(400, "malformed header line");
        };
        let name = name.trim();
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            let Ok(n) = value.parse::<u64>() else {
                return bad(400, "unparseable Content-Length");
            };
            if n > MAX_HTTP_BODY_BYTES as u64 {
                return bad(
                    413,
                    format!(
                        "Content-Length {n} exceeds the {} byte \
                         limit",
                        MAX_HTTP_BODY_BYTES
                    ),
                );
            }
            let n = n as usize;
            if content_length.is_some_and(|prev| prev != n) {
                return bad(400, "conflicting Content-Length");
            }
            content_length = Some(n);
        } else if name.eq_ignore_ascii_case("transfer-encoding") {
            return bad(501, "chunked bodies are not supported");
        } else if name.eq_ignore_ascii_case("connection") {
            if value.eq_ignore_ascii_case("close") {
                keep_alive = false;
            } else if value.eq_ignore_ascii_case("keep-alive") {
                keep_alive = true;
            }
        }
    }
    let body_len = content_length.unwrap_or(0);
    let total = head_end + body_len;
    if buf.len() < total {
        return Parse::Incomplete;
    }
    Parse::Ok(HttpRequest {
        method: method.to_string(),
        path: path.to_string(),
        keep_alive,
        body: buf[head_end..total].to_vec(),
        consumed: total,
    })
}

/// Byte offset just past the `\r\n\r\n` head terminator.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|i| i + 4)
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        501 => "Not Implemented",
        _ => "Error",
    }
}

/// Encode a JSON response (the gateway speaks nothing else).
pub fn encode_response(
    status: u16,
    body: &str,
    keep_alive: bool,
) -> Vec<u8> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: {}\r\n\r\n",
        status,
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    let mut out = Vec::with_capacity(head.len() + body.len());
    out.extend_from_slice(head.as_bytes());
    out.extend_from_slice(body.as_bytes());
    out
}

/// Encode the standard `{"error": msg}` JSON failure body.
pub fn error_body(msg: &str) -> String {
    crate::json::Value::obj(vec![(
        "error",
        crate::json::Value::Str(msg.to_string()),
    )])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ok(buf: &[u8]) -> HttpRequest {
        match parse_request(buf) {
            Parse::Ok(r) => r,
            other => panic!("expected Ok, got {other:?}"),
        }
    }

    #[test]
    fn parses_get_and_post() {
        let r = ok(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/metrics");
        assert!(r.keep_alive);
        assert!(r.body.is_empty());
        let raw = b"POST /v1/predict HTTP/1.1\r\n\
                    Content-Length: 9\r\n\r\n{\"x\":[[]]}";
        // content-length 9 < body 10: only 9 bytes consumed
        let r = ok(&raw[..]);
        assert_eq!(r.body, b"{\"x\":[[]]".to_vec());
        assert_eq!(r.consumed, raw.len() - 1);
    }

    #[test]
    fn keep_alive_rules() {
        assert!(!ok(b"GET / HTTP/1.0\r\n\r\n").keep_alive);
        assert!(
            ok(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n")
                .keep_alive
        );
        assert!(
            !ok(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n")
                .keep_alive
        );
    }

    #[test]
    fn incomplete_inputs_wait_for_more() {
        for prefix in [
            &b"GET /metrics HTTP/1.1\r\n"[..],
            &b"POST /p HTTP/1.1\r\nContent-Length: 5\r\n\r\nab"[..],
        ] {
            assert!(matches!(
                parse_request(prefix),
                Parse::Incomplete
            ));
        }
    }

    #[test]
    fn hostile_inputs_rejected_with_status() {
        let cases: Vec<(Vec<u8>, u16)> = vec![
            (b"garbage\r\n\r\n".to_vec(), 400),
            (b"GET nopath HTTP/1.1\r\n\r\n".to_vec(), 400),
            (b"GET / HTTP/9.9\r\n\r\n".to_vec(), 400),
            (
                b"POST / HTTP/1.1\r\nContent-Length: 999999999999\
                  \r\n\r\n"
                    .to_vec(),
                413,
            ),
            (
                b"POST / HTTP/1.1\r\nContent-Length: -4\r\n\r\n"
                    .to_vec(),
                400,
            ),
            (
                b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\
                  \r\n\r\n"
                    .to_vec(),
                501,
            ),
            (
                b"POST / HTTP/1.1\r\nContent-Length: 4\r\n\
                  Content-Length: 5\r\n\r\n"
                    .to_vec(),
                400,
            ),
        ];
        let mut long_head = b"GET /".to_vec();
        long_head.resize(MAX_HEAD_BYTES + 10, b'a');
        let cases = cases
            .into_iter()
            .chain(std::iter::once((long_head, 431)));
        for (buf, want) in cases {
            match parse_request(&buf) {
                Parse::Bad { status, .. } => {
                    assert_eq!(status, want, "input {buf:?}")
                }
                other => panic!(
                    "expected Bad({want}) for {buf:?}, got {other:?}"
                ),
            }
        }
    }

    #[test]
    fn response_encoding_is_framed() {
        let out = encode_response(200, "{\"a\":1}", true);
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 7\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"a\":1}"));
        let closed = encode_response(429, &error_body("shed"), false);
        let text = String::from_utf8(closed).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests"));
        assert!(text.contains("Connection: close"));
        assert!(text.contains("{\"error\":\"shed\"}"));
    }
}
