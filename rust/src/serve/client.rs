//! A small blocking client for the decode server — used by the CLI,
//! the integration tests and as reference documentation for the wire
//! protocol ([`super::protocol`]).

use std::io::{BufReader, BufWriter, ErrorKind, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::protocol::{
    read_response, write_request, Request, Response,
};
use crate::error::{invalid, Result};
use crate::json::{self, Value};
use crate::volume::FeatureMatrix;

/// Total connect retry budget on `ConnectionRefused` — covers the
/// race where a client starts before the server's listener is up.
const CONNECT_RETRY_BUDGET: Duration = Duration::from_secs(2);

/// First retry backoff; doubles per attempt up to the budget.
const CONNECT_BACKOFF_START: Duration = Duration::from_millis(10);

/// One TCP connection to a running decode server.
pub struct ServeClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl ServeClient {
    /// Connect to a server started by [`super::Server::start`].
    ///
    /// `ConnectionRefused` is retried with doubling backoff for up
    /// to ~2 s — enough to ride out a server that is still binding —
    /// so callers racing a fresh server don't need their own retry
    /// loops. Every other error is immediate.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        let mut backoff = CONNECT_BACKOFF_START;
        let mut spent = Duration::ZERO;
        let stream = loop {
            match TcpStream::connect(&addr) {
                Ok(s) => break s,
                Err(e)
                    if e.kind() == ErrorKind::ConnectionRefused
                        && spent < CONNECT_RETRY_BUDGET =>
                {
                    std::thread::sleep(backoff);
                    spent += backoff;
                    backoff *= 2;
                }
                Err(e) => return Err(e.into()),
            }
        };
        stream.set_nodelay(true)?;
        let read_half = stream.try_clone()?;
        Ok(ServeClient {
            reader: BufReader::new(read_half),
            writer: BufWriter::new(stream),
        })
    }

    fn call(&mut self, rq: &Request) -> Result<Response> {
        write_request(&mut self.writer, rq)?;
        self.writer.flush()?;
        match read_response(&mut self.reader)? {
            Response::Error(msg) => {
                Err(invalid(format!("server error: {msg}")))
            }
            Response::Shed(msg) => {
                Err(invalid(format!("server shedding load: {msg}")))
            }
            rs => Ok(rs),
        }
    }

    /// Summary of the server's default model, as parsed JSON.
    pub fn model_info(&mut self) -> Result<Value> {
        self.model_info_named("")
    }

    /// Summary of a named model in the server's model directory.
    pub fn model_info_named(&mut self, model: &str) -> Result<Value> {
        match self
            .call(&Request::ModelInfo { model: model.to_string() })?
        {
            Response::Info(text) => json::parse(&text),
            other => {
                Err(invalid(format!("unexpected response {other:?}")))
            }
        }
    }

    /// Reduce a `(c, p)` sample-major block to `(c, k)` on the
    /// server's default model.
    pub fn compress(
        &mut self,
        x: &FeatureMatrix,
    ) -> Result<FeatureMatrix> {
        match self.call(&Request::Compress {
            model: String::new(),
            x: x.clone(),
        })? {
            Response::Compressed(xk) => Ok(xk),
            other => {
                Err(invalid(format!("unexpected response {other:?}")))
            }
        }
    }

    /// Ensemble class-1 probabilities for a `(c, p)` block on the
    /// server's default model.
    pub fn predict(&mut self, x: &FeatureMatrix) -> Result<Vec<f32>> {
        match self.call(&Request::Predict {
            model: String::new(),
            x: x.clone(),
        })? {
            Response::Probabilities(p) => Ok(p),
            other => {
                Err(invalid(format!("unexpected response {other:?}")))
            }
        }
    }

    /// Write every request back-to-back, then read every response —
    /// the pipelined pattern the server's per-connection batching is
    /// built for. Responses come back in request order; request-level
    /// failures appear as [`Response::Error`] entries rather than
    /// failing the whole pipeline.
    pub fn call_pipelined(
        &mut self,
        rqs: &[Request],
    ) -> Result<Vec<Response>> {
        for rq in rqs {
            write_request(&mut self.writer, rq)?;
        }
        self.writer.flush()?;
        let mut out = Vec::with_capacity(rqs.len());
        for _ in rqs {
            out.push(read_response(&mut self.reader)?);
        }
        Ok(out)
    }
}
