//! The multi-model registry (ADR-008): dozens of versioned `.fcm`
//! models resident in one process, each behind the lazily-validated
//! memory mapping of [`crate::model::MappedModel`], evicted by
//! **resident bytes** rather than entry count, and hot-reloaded
//! atomically when the file on disk changes.
//!
//! This replaces the count-capped LRU of the PR 4 `ModelCache`: a
//! count cap is the wrong knob once models stop costing their full
//! file size (a mapped model that only ever answered `model-info`
//! holds O(header) bytes), and a fleet wants a *byte* budget the
//! operator can size against the machine.
//!
//! # Semantics
//!
//! * **Get**: a resident entry is re-stamped (`len` + `mtime` from
//!   one `stat(2)`) on every lookup. An unchanged stamp is a hit —
//!   no payload I/O at all.
//! * **Hot reload**: a changed stamp triggers a reopen *outside the
//!   registry lock*. If the new mapping's section fingerprint
//!   (per-section `(len, crc)` pairs, read from the index without
//!   validating payloads) matches the resident one, the change was
//!   cosmetic (`touch`, rewrite-with-same-bytes) and the old mapping
//!   is kept. Otherwise the `Arc` is swapped atomically: requests
//!   already holding the old `Arc` finish on the old bytes (the old
//!   inode stays mapped until the last clone drops — which is why
//!   deploys must *rename-replace*, never truncate in place; see
//!   [`crate::model::mmap`]).
//! * **Reload failure keeps serving**: if the changed file fails to
//!   open or validate, the resident model stays and the failure is
//!   counted (`reload_errors`) — a bad deploy must not take down the
//!   models already in memory.
//! * **Eviction**: after an insert or reload, least-recently-used
//!   entries are dropped until the *measured* resident total (sum of
//!   [`MappedModel::resident_bytes`], which grows as sections are
//!   touched) fits the budget. The entry being returned is never
//!   evicted, so a single over-budget model still serves.
//!
//! Cold loads and reloads both run without the lock held (the PR 4
//! dogpile trade-off is kept: concurrent cold misses on one model
//! may each open it; first insert wins).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::SystemTime;

use crate::error::Result;
use crate::json::Value;
use crate::model::{open_model, MappedModel};

/// `stat(2)` snapshot used for change detection.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
struct FileStamp {
    len: u64,
    mtime: Option<SystemTime>,
}

fn stamp(path: &Path) -> Result<FileStamp> {
    let md = std::fs::metadata(path)?;
    Ok(FileStamp { len: md.len(), mtime: md.modified().ok() })
}

struct Entry {
    model: Arc<MappedModel>,
    stamp: FileStamp,
    last_used: u64,
    hits: u64,
    reloads: u64,
    reload_errors: u64,
}

struct RegistryState {
    map: HashMap<PathBuf, Entry>,
    clock: u64,
    loads: u64,
    hits: u64,
    reloads: u64,
    reload_errors: u64,
    evictions: u64,
}

/// Byte-budget LRU of lazily-mapped models, keyed by path.
pub struct ModelRegistry {
    max_bytes: u64,
    state: Mutex<RegistryState>,
}

impl ModelRegistry {
    /// Create with a resident-byte budget (min 1 — a zero budget
    /// would still have to hold the entry it is returning).
    pub fn new(max_bytes: u64) -> Self {
        ModelRegistry {
            max_bytes: max_bytes.max(1),
            state: Mutex::new(RegistryState {
                map: HashMap::new(),
                clock: 0,
                loads: 0,
                hits: 0,
                reloads: 0,
                reload_errors: 0,
                evictions: 0,
            }),
        }
    }

    /// The configured resident-byte budget.
    pub fn max_bytes(&self) -> u64 {
        self.max_bytes
    }

    /// Resident model count.
    pub fn len(&self) -> usize {
        self.state.lock().expect("registry poisoned").map.len()
    }

    /// Whether the registry holds nothing.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Files opened from disk so far (cold loads + reloads) — the
    /// `GET /metrics` `cache_loads` field.
    pub fn loads(&self) -> u64 {
        let st = self.state.lock().expect("registry poisoned");
        st.loads + st.reloads
    }

    /// Lookups served by a resident mapping — the `GET /metrics`
    /// `cache_hits` field.
    pub fn hits(&self) -> u64 {
        self.state.lock().expect("registry poisoned").hits
    }

    /// Hot reloads that swapped in changed bytes.
    pub fn reloads(&self) -> u64 {
        self.state.lock().expect("registry poisoned").reloads
    }

    /// Measured resident bytes across every entry (grows as lazy
    /// sections get touched).
    pub fn resident_bytes(&self) -> u64 {
        let st = self.state.lock().expect("registry poisoned");
        st.map.values().map(|e| e.model.resident_bytes()).sum()
    }

    /// Fetch the model at `path`, opening it lazily on miss and
    /// hot-reloading it if the file changed since it was mapped. See
    /// the module docs for the full get/reload/evict contract.
    pub fn get_or_load(&self, path: &Path) -> Result<Arc<MappedModel>> {
        let now = stamp(path);
        {
            let mut st = self.state.lock().expect("registry poisoned");
            st.clock += 1;
            let tick = st.clock;
            if let Some(e) = st.map.get_mut(path) {
                e.last_used = tick;
                match &now {
                    Ok(s) if *s == e.stamp => {
                        e.hits += 1;
                        st.hits += 1;
                        return Ok(e.model.clone());
                    }
                    Err(_) => {
                        // stat raced a rename-replace: serve the
                        // resident bytes, next get re-checks
                        e.hits += 1;
                        st.hits += 1;
                        return Ok(e.model.clone());
                    }
                    Ok(_) => {} // stamp moved: fall through to reload
                }
            }
        }
        // cold miss or stale stamp: open with the lock released so
        // requests against resident models keep flowing
        let opened = open_model(path);
        let mut st = self.state.lock().expect("registry poisoned");
        st.clock += 1;
        let tick = st.clock;
        if let Some(e) = st.map.get_mut(path) {
            e.last_used = tick;
            let fresh = match opened {
                Ok(m) => m,
                Err(_) => {
                    // bad deploy: keep serving the resident model
                    e.reload_errors += 1;
                    st.reload_errors += 1;
                    return Ok(e.model.clone());
                }
            };
            if fresh.section_fingerprint()
                == e.model.section_fingerprint()
            {
                // same bytes (touch / idempotent rewrite): keep the
                // warm mapping, just refresh the stamp
                if let Ok(s) = stamp(path) {
                    e.stamp = s;
                }
                e.hits += 1;
                st.hits += 1;
                return Ok(e.model.clone());
            }
            // atomic swap: in-flight requests finish on the old Arc
            e.model = Arc::new(fresh);
            if let Ok(s) = stamp(path) {
                e.stamp = s;
            }
            e.reloads += 1;
            st.reloads += 1;
            let model = e.model.clone();
            self.evict_over_budget(&mut st, path);
            return Ok(model);
        }
        let model = Arc::new(opened?);
        st.loads += 1;
        let entry_stamp = now.or_else(|_| stamp(path))?;
        st.map.insert(
            path.to_path_buf(),
            Entry {
                model: model.clone(),
                stamp: entry_stamp,
                last_used: tick,
                hits: 0,
                reloads: 0,
                reload_errors: 0,
            },
        );
        self.evict_over_budget(&mut st, path);
        Ok(model)
    }

    /// Drop LRU entries until the measured resident total fits the
    /// budget, never evicting `keep`.
    fn evict_over_budget(&self, st: &mut RegistryState, keep: &Path) {
        loop {
            let total: u64 = st
                .map
                .values()
                .map(|e| e.model.resident_bytes())
                .sum();
            if total <= self.max_bytes || st.map.len() <= 1 {
                return;
            }
            let victim = st
                .map
                .iter()
                .filter(|(p, _)| p.as_path() != keep)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(p, _)| p.clone());
            match victim {
                Some(p) => {
                    st.map.remove(&p);
                    st.evictions += 1;
                }
                None => return,
            }
        }
    }

    /// Per-model + aggregate stats for `GET /metrics`: residency,
    /// laziness (validated payload vs file bytes), hit/reload
    /// counters. Keys are the model paths the clients used.
    pub fn stats_json(&self) -> Value {
        let st = self.state.lock().expect("registry poisoned");
        let mut entries: Vec<(&PathBuf, &Entry)> =
            st.map.iter().collect();
        entries.sort_by(|a, b| a.0.cmp(b.0));
        let per_model = Value::Obj(
            entries
                .into_iter()
                .map(|(p, e)| {
                    (
                        p.display().to_string(),
                        Value::obj(vec![
                            (
                                "resident_bytes",
                                Value::Num(
                                    e.model.resident_bytes() as f64,
                                ),
                            ),
                            (
                                "validated_payload_bytes",
                                Value::Num(
                                    e.model.validated_payload_bytes()
                                        as f64,
                                ),
                            ),
                            (
                                "file_bytes",
                                Value::Num(e.model.file_len() as f64),
                            ),
                            (
                                "mapped",
                                Value::Bool(e.model.is_mapped()),
                            ),
                            ("hits", Value::Num(e.hits as f64)),
                            ("reloads", Value::Num(e.reloads as f64)),
                            (
                                "reload_errors",
                                Value::Num(e.reload_errors as f64),
                            ),
                        ]),
                    )
                })
                .collect(),
        );
        let resident: u64 =
            st.map.values().map(|e| e.model.resident_bytes()).sum();
        Value::obj(vec![
            ("max_bytes", Value::Num(self.max_bytes as f64)),
            ("resident_bytes", Value::Num(resident as f64)),
            ("resident_models", Value::Num(st.map.len() as f64)),
            ("loads", Value::Num(st.loads as f64)),
            ("hits", Value::Num(st.hits as f64)),
            ("reloads", Value::Num(st.reloads as f64)),
            (
                "reload_errors",
                Value::Num(st.reload_errors as f64),
            ),
            ("evictions", Value::Num(st.evictions as f64)),
            ("models", per_model),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{
        DataConfig, EstimatorConfig, Method, ReduceConfig,
    };
    use crate::model::{fit_model, save_model, FitOptions};
    use crate::volume::MorphometryGenerator;

    /// Fit + save a tiny model under a unique stem; returns the path.
    fn saved_model(tag: &str, seed: u64, note: &str) -> PathBuf {
        let dc = DataConfig {
            dims: [8, 9, 7],
            n_samples: 24,
            seed,
            ..Default::default()
        };
        let (ds, y) = MorphometryGenerator::new(dc.dims)
            .generate(dc.n_samples, seed);
        let reduce = ReduceConfig {
            method: Method::Fast,
            ratio: 10,
            ..Default::default()
        };
        let est = EstimatorConfig {
            cv_folds: 3,
            max_iter: 60,
            ..Default::default()
        };
        let opts = FitOptions {
            note: note.to_string(),
            ..Default::default()
        };
        let model =
            fit_model(&ds, &y, &reduce, &est, &dc, &opts).unwrap();
        let dir = std::env::temp_dir().join("fastclust_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{tag}.fcm"));
        // rename-replacement, as the mmap safety contract requires
        let tmp = dir.join(format!("{tag}.fcm.tmp"));
        save_model(&tmp, &model).unwrap();
        std::fs::rename(&tmp, &path).unwrap();
        path
    }

    #[test]
    fn hit_shares_the_same_arc() {
        let path = saved_model("hit", 1, "a");
        let reg = ModelRegistry::new(1 << 30);
        let a = reg.get_or_load(&path).unwrap();
        let b = reg.get_or_load(&path).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second get must be a hit");
        assert_eq!(reg.loads(), 1);
        assert_eq!(reg.hits(), 1);
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn byte_budget_evicts_least_recently_used() {
        let p1 = saved_model("ev1", 1, "a");
        let p2 = saved_model("ev2", 2, "a");
        let p3 = saved_model("ev3", 3, "a");
        let reg = ModelRegistry::new(1 << 30);
        let m1 = reg.get_or_load(&p1).unwrap();
        // force residency past O(header): decode everything
        m1.to_fitted().unwrap();
        let one = m1.resident_bytes();
        drop(m1);
        // room for ~2 fully-decoded models, not 3
        let reg = ModelRegistry::new(one * 2 + one / 2);
        reg.get_or_load(&p1).unwrap().to_fitted().unwrap();
        reg.get_or_load(&p2).unwrap().to_fitted().unwrap();
        reg.get_or_load(&p1).unwrap(); // p1 most recent
        reg.get_or_load(&p3).unwrap().to_fitted().unwrap();
        assert!(reg.len() <= 2, "resident: {}", reg.len());
        assert_eq!(reg.loads(), 3);
        reg.get_or_load(&p1).unwrap(); // survived (most recent)
        assert_eq!(reg.loads(), 3);
        reg.get_or_load(&p2).unwrap(); // was evicted: reloads
        assert_eq!(reg.loads(), 4);
    }

    #[test]
    fn lazy_entries_fit_where_decoded_ones_would_not() {
        // the point of byte-based eviction: models that were only
        // header-probed stay cheap, so many fit a small budget
        let p1 = saved_model("lz1", 1, "a");
        let p2 = saved_model("lz2", 2, "a");
        let p3 = saved_model("lz3", 3, "a");
        let probe = ModelRegistry::new(1 << 30);
        let full = probe.get_or_load(&p1).unwrap();
        full.to_fitted().unwrap();
        let decoded = full.resident_bytes();
        // budget below 2 decoded models but far above 3 lazy ones
        let reg = ModelRegistry::new(decoded + decoded / 2);
        for p in [&p1, &p2, &p3] {
            reg.get_or_load(p).unwrap();
        }
        assert_eq!(reg.len(), 3, "header-only entries must all fit");
        assert!(reg.resident_bytes() < decoded);
    }

    #[test]
    fn hot_reload_swaps_changed_bytes() {
        let path = saved_model("hot", 1, "v1");
        let reg = ModelRegistry::new(1 << 30);
        let before = reg.get_or_load(&path).unwrap();
        assert_eq!(before.header().note, "v1");
        // note length differs → len differs → stamp moves even if
        // mtime granularity is coarse
        saved_model("hot", 1, "v2-longer-note");
        let after = reg.get_or_load(&path).unwrap();
        assert_eq!(after.header().note, "v2-longer-note");
        assert!(!Arc::ptr_eq(&before, &after));
        assert_eq!(reg.reloads(), 1);
        // the old Arc still serves its original bytes
        assert_eq!(before.header().note, "v1");
    }

    #[test]
    fn reload_failure_keeps_serving_resident_model() {
        let path = saved_model("badreload", 1, "good");
        let reg = ModelRegistry::new(1 << 30);
        let good = reg.get_or_load(&path).unwrap();
        // corrupt the file in place (different len → stamp moves)
        std::fs::write(&path, b"FCMODEL1 garbage").unwrap();
        let still = reg.get_or_load(&path).unwrap();
        assert!(Arc::ptr_eq(&good, &still));
        assert_eq!(still.header().note, "good");
        assert_eq!(reg.reloads(), 0);
        let stats = reg.stats_json();
        assert_eq!(
            stats
                .get("reload_errors")
                .unwrap()
                .as_u64()
                .unwrap(),
            1
        );
    }

    #[test]
    fn identical_rewrite_is_not_a_reload() {
        let path = saved_model("samebytes", 1, "same");
        let reg = ModelRegistry::new(1 << 30);
        let a = reg.get_or_load(&path).unwrap();
        // rewrite identical bytes through a rename (mtime moves,
        // fingerprint does not)
        let bytes = std::fs::read(&path).unwrap();
        let tmp = path.with_extension("fcm.tmp");
        std::fs::write(&tmp, &bytes).unwrap();
        std::fs::rename(&tmp, &path).unwrap();
        let b = reg.get_or_load(&path).unwrap();
        assert!(
            Arc::ptr_eq(&a, &b),
            "identical bytes must keep the warm mapping"
        );
        assert_eq!(reg.reloads(), 0);
    }

    #[test]
    fn missing_file_is_an_error_not_a_panic() {
        let reg = ModelRegistry::new(1 << 20);
        assert!(reg
            .get_or_load(Path::new("/nonexistent/m.fcm"))
            .is_err());
        assert!(reg.is_empty());
    }

    #[test]
    fn stats_json_reports_per_model_residency() {
        let path = saved_model("stats", 1, "s");
        let reg = ModelRegistry::new(1 << 30);
        let m = reg.get_or_load(&path).unwrap();
        reg.get_or_load(&path).unwrap();
        let v = reg.stats_json();
        assert_eq!(
            v.get("resident_models").unwrap().as_u64().unwrap(),
            1
        );
        assert_eq!(v.get("loads").unwrap().as_u64().unwrap(), 1);
        assert_eq!(v.get("hits").unwrap().as_u64().unwrap(), 1);
        let key = path.display().to_string();
        let per = v.get("models").unwrap().get(&key).unwrap();
        let resident =
            per.get("resident_bytes").unwrap().as_u64().unwrap();
        assert!(resident > 0);
        assert!(resident < m.file_len());
        assert!(per.get("mapped").unwrap().as_bool().is_some());
        assert!(crate::json::parse(&v.to_string()).is_ok());
    }
}
