//! Server-side observability counters (ADR-007 §Metrics): lock-free
//! atomics for the hot-path counts, log-scale histograms for batch
//! sizes and request latency, and a mutexed per-model request map —
//! snapshotted into the JSON the `GET /metrics` endpoint serves.
//!
//! Histograms use power-of-two buckets (`bucket i` counts values in
//! `(2^(i-1), 2^i]`), so recording is one atomic add and quantiles
//! are a cumulative walk; the reported quantile is the bucket's
//! upper bound — a ≤2x overestimate, which is the right bias for a
//! p99 used in regression gates.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::json::Value;

/// Buckets in each histogram: values up to `2^(N-1)`, plus an
/// overflow bucket. 24 covers latencies to ~8.4 s in microseconds
/// and any plausible batch size.
const HIST_BUCKETS: usize = 24;

/// A log2-bucketed counting histogram.
struct LogHist {
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl LogHist {
    fn new() -> LogHist {
        LogHist {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    fn record(&self, v: u64) {
        let idx = (64 - v.max(1).leading_zeros() as usize
            - (v.max(1).is_power_of_two() as usize))
        .min(HIST_BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    fn counts(&self) -> [u64; HIST_BUCKETS] {
        std::array::from_fn(|i| {
            self.buckets[i].load(Ordering::Relaxed)
        })
    }

    /// Upper bound of the bucket holding quantile `q` (0 when the
    /// histogram is empty).
    fn quantile(&self, q: f64) -> u64 {
        let counts = self.counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target =
            ((total as f64) * q).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << i;
            }
        }
        1u64 << (HIST_BUCKETS - 1)
    }
}

/// All counters the server exports (shared via `Arc` between the
/// event loop, the worker jobs and `GET /metrics`).
pub struct Metrics {
    /// Sockets accepted (admitted + shed).
    pub accepted: AtomicU64,
    /// Sockets rejected by the connection budget (never silent: each
    /// got an explicit shed frame / 429 before the close).
    pub shed: AtomicU64,
    /// Requests answered, across both front-ends.
    pub requests: AtomicU64,
    /// Requests that arrived over the HTTP gateway.
    pub http_requests: AtomicU64,
    /// Kernel-pass batches executed on the worker pool.
    pub batches: AtomicU64,
    /// Requests answered with an error response.
    pub errors: AtomicU64,
    /// Connections reaped by the idle deadline (ADR-010): a peer
    /// that went quiet mid-request or sat idle past
    /// `--idle-timeout-ms` was closed to free its budget slot.
    pub idle_closed: AtomicU64,
    batch_sizes: LogHist,
    latency_us: LogHist,
    per_model: Mutex<BTreeMap<String, u64>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    /// Fresh zeroed registry.
    pub fn new() -> Metrics {
        Metrics {
            accepted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            http_requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            idle_closed: AtomicU64::new(0),
            batch_sizes: LogHist::new(),
            latency_us: LogHist::new(),
            per_model: Mutex::new(BTreeMap::new()),
        }
    }

    /// Record one executed batch of `size` requests.
    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_sizes.record(size as u64);
    }

    /// Record one request's queue-to-encode latency.
    pub fn record_latency_us(&self, us: u64) {
        self.latency_us.record(us);
    }

    /// Attribute `n` requests to a model name ("" = the default).
    pub fn record_model(&self, name: &str, n: u64) {
        let key = if name.is_empty() { "<default>" } else { name };
        let mut map =
            self.per_model.lock().expect("metrics poisoned");
        *map.entry(key.to_string()).or_insert(0) += n;
    }

    /// Latency quantile in microseconds (bucket upper bound).
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        self.latency_us.quantile(q)
    }

    /// Snapshot everything as the `GET /metrics` JSON body. Registry
    /// numbers come from the caller ([`super::ModelRegistry`] owns
    /// them): `cache_loads`/`cache_hits` stay top-level for
    /// dashboard compatibility with the PR 4 cache, and the full
    /// per-model residency/hit/reload breakdown (ADR-008) lands
    /// under the `registry` key.
    pub fn to_json(
        &self,
        cache_loads: u64,
        cache_hits: u64,
        registry: Value,
    ) -> Value {
        let load = |c: &AtomicU64| {
            Value::Num(c.load(Ordering::Relaxed) as f64)
        };
        let hist = |h: &LogHist| {
            let counts = h.counts();
            let last = counts
                .iter()
                .rposition(|&c| c != 0)
                .map(|i| i + 1)
                .unwrap_or(0);
            Value::Arr(
                (0..last)
                    .map(|i| {
                        Value::obj(vec![
                            ("le", Value::Num((1u64 << i) as f64)),
                            ("count", Value::Num(counts[i] as f64)),
                        ])
                    })
                    .collect(),
            )
        };
        let models = {
            let map =
                self.per_model.lock().expect("metrics poisoned");
            Value::Obj(
                map.iter()
                    .map(|(k, &v)| {
                        (
                            k.clone(),
                            Value::obj(vec![(
                                "requests",
                                Value::Num(v as f64),
                            )]),
                        )
                    })
                    .collect(),
            )
        };
        Value::obj(vec![
            ("accepted", load(&self.accepted)),
            ("shed", load(&self.shed)),
            ("requests", load(&self.requests)),
            ("http_requests", load(&self.http_requests)),
            ("batches", load(&self.batches)),
            ("errors", load(&self.errors)),
            ("idle_closed", load(&self.idle_closed)),
            ("batch_size_hist", hist(&self.batch_sizes)),
            ("latency_us_hist", hist(&self.latency_us)),
            (
                "latency_us_p50",
                Value::Num(self.latency_us.quantile(0.50) as f64),
            ),
            (
                "latency_us_p99",
                Value::Num(self.latency_us.quantile(0.99) as f64),
            ),
            ("cache_loads", Value::Num(cache_loads as f64)),
            ("cache_hits", Value::Num(cache_hits as f64)),
            ("registry", registry),
            ("models", models),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = LogHist::new();
        // bucket edges: 1→0, 2→1, 3..4→2, 5..8→3
        for v in [1, 2, 3, 4, 5, 8] {
            h.record(v);
        }
        let c = h.counts();
        assert_eq!(c[0], 1);
        assert_eq!(c[1], 1);
        assert_eq!(c[2], 2);
        assert_eq!(c[3], 2);
        assert_eq!(h.quantile(0.5), 4);
        assert_eq!(h.quantile(1.0), 8);
        // empty histogram reports 0
        assert_eq!(LogHist::new().quantile(0.99), 0);
        // overflow clamps to the last bucket
        let big = LogHist::new();
        big.record(u64::MAX);
        assert_eq!(
            big.quantile(1.0),
            1u64 << (HIST_BUCKETS - 1)
        );
    }

    #[test]
    fn metrics_json_shape() {
        let m = Metrics::new();
        m.accepted.fetch_add(3, Ordering::Relaxed);
        m.shed.fetch_add(1, Ordering::Relaxed);
        m.requests.fetch_add(10, Ordering::Relaxed);
        m.record_batch(4);
        m.record_latency_us(250);
        m.record_model("", 6);
        m.record_model("other.fcm", 4);
        let reg = Value::obj(vec![(
            "resident_bytes",
            Value::Num(1234.0),
        )]);
        let v = m.to_json(2, 8, reg);
        assert_eq!(v.get("accepted").unwrap().as_u64().unwrap(), 3);
        assert_eq!(v.get("shed").unwrap().as_u64().unwrap(), 1);
        assert_eq!(v.get("batches").unwrap().as_u64().unwrap(), 1);
        assert_eq!(
            v.get("cache_hits").unwrap().as_u64().unwrap(),
            8
        );
        assert_eq!(
            v.get("registry")
                .unwrap()
                .get("resident_bytes")
                .unwrap()
                .as_u64()
                .unwrap(),
            1234
        );
        assert!(
            v.get("latency_us_p99").unwrap().as_u64().unwrap()
                >= 250
        );
        let models = v.get("models").unwrap();
        assert_eq!(
            models
                .get("<default>")
                .unwrap()
                .get("requests")
                .unwrap()
                .as_u64()
                .unwrap(),
            6
        );
        // the snapshot is valid, parseable JSON
        assert!(crate::json::parse(&v.to_string()).is_ok());
    }
}
