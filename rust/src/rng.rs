//! Deterministic, dependency-free pseudo-random numbers.
//!
//! Every stochastic component in the library (synthetic data, random
//! projections, k-means init, rand-single edge deletion, CV shuffles)
//! takes an explicit `u64` seed and derives an independent stream via
//! [`Rng::derive`], so whole experiments are bit-reproducible from a
//! single root seed — a requirement for the paper-reproduction harness
//! (EXPERIMENTS.md records seeds next to every number).
//!
//! Generator: xoshiro256++ seeded through SplitMix64, the standard
//! construction recommended by Blackman & Vigna.

/// xoshiro256++ PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed (any value, including 0, is fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream for a named sub-task.
    /// Streams for different tags never collide in practice.
    pub fn derive(&self, tag: u64) -> Rng {
        Rng::new(
            self.s[0]
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(tag)
                .rotate_left(17)
                ^ self.s[2],
        )
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's rejection method to
    /// avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n || n.is_power_of_two() {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; throughput is not RNG-bound anywhere in the crate).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard normal as `f32`.
    #[inline]
    pub fn normal32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with i.i.d. standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal32();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `m` distinct indices from `0..n` (Floyd's algorithm would
    /// be fancier; partial shuffle is simple and O(m) swaps).
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "cannot sample {m} from {n}");
        let mut p: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + self.below(n - i);
            p.swap(i, j);
        }
        p.truncate(m);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn derive_is_independent() {
        let root = Rng::new(7);
        let mut c1 = root.derive(1);
        let mut c2 = root.derive(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(6);
        let mut p = r.permutation(100);
        p.sort_unstable();
        assert_eq!(p, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(8);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }
}
