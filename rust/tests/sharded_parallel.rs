//! Property tests for the sharded parallel clustering engine
//! (docs/adr/002): across random `SyntheticCube` instances, the sharded
//! and single-thread engines must both return exactly-`k`, spatially
//! connected, non-percolating partitions — and the sharded partition's
//! quality (the Fig-5 variance-ratio metric) must stay within 5% of
//! single-thread.
#![allow(clippy::needless_range_loop)] // indexed loops mirror the math

use fastclust::cluster::{
    Clusterer, FastCluster, Labels, ShardedFastCluster,
};
use fastclust::graph::{LatticeGraph, PartitionStrategy};
use fastclust::reduce::{ClusterReduce, Reducer};
use fastclust::rng::Rng;
use fastclust::stats::{median, variance_ratio_per_voxel};
use fastclust::volume::{ContrastMapGenerator, SyntheticCube};

fn assert_connected(labels: &Labels, g: &LatticeGraph, ctx: &str) {
    for cl in 0..labels.k as u32 {
        let members: Vec<usize> = (0..labels.p())
            .filter(|&i| labels.labels[i] == cl)
            .collect();
        assert!(!members.is_empty(), "{ctx}: cluster {cl} empty");
        let mut seen = vec![false; labels.p()];
        let mut stack = vec![members[0]];
        seen[members[0]] = true;
        let mut cnt = 0;
        while let Some(v) = stack.pop() {
            cnt += 1;
            for &nb in g.neighbors(v) {
                let nb = nb as usize;
                if !seen[nb] && labels.labels[nb] == cl {
                    seen[nb] = true;
                    stack.push(nb);
                }
            }
        }
        assert_eq!(
            cnt,
            members.len(),
            "{ctx}: cluster {cl} spatially disconnected"
        );
    }
}

/// Both engines: exactly k non-empty, spatially connected clusters on
/// random cube instances, across shard counts and both partition
/// strategies.
#[test]
fn sharded_and_single_produce_valid_k_partitions() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed);
        let dims =
            [6 + rng.below(5), 6 + rng.below(5), 5 + rng.below(4)];
        let n = 2 + rng.below(4);
        let ds = SyntheticCube::new(dims, 4.0, 0.6).generate(n, seed ^ 0x5EED);
        let g = LatticeGraph::from_mask(ds.mask());
        let p = ds.p();
        let k = (4 + rng.below(p / 4)).min(p);

        let single = FastCluster::default()
            .fit(ds.data(), &g, k, seed)
            .unwrap();
        assert_eq!(single.k, k, "seed {seed}: single-thread k");
        assert_connected(&single, &g, &format!("seed {seed} single"));

        for shards in [2usize, 4] {
            for strategy in [
                PartitionStrategy::IndexSlabs,
                PartitionStrategy::BfsBisection,
            ] {
                let engine = ShardedFastCluster {
                    n_shards: shards,
                    strategy,
                    ..Default::default()
                };
                let ctx = format!(
                    "seed {seed} shards {shards} {strategy:?}"
                );
                let labels =
                    engine.fit(ds.data(), &g, k, seed).unwrap();
                assert_eq!(labels.k, k, "{ctx}: wrong k");
                assert!(
                    labels.sizes().iter().all(|&s| s > 0),
                    "{ctx}: empty cluster"
                );
                assert_connected(&labels, &g, &ctx);
            }
        }
    }
}

/// The sharded engine never percolates: max cluster size stays near
/// p/k, exactly like the single-thread guarantee.
#[test]
fn sharded_partition_does_not_percolate() {
    let ds = SyntheticCube::new([14, 14, 12], 5.0, 0.8).generate(3, 11);
    let g = LatticeGraph::from_mask(ds.mask());
    let p = ds.p();
    let k = p / 10;
    for shards in [2usize, 4, 8] {
        let engine =
            ShardedFastCluster { n_shards: shards, ..Default::default() };
        let labels = engine.fit(ds.data(), &g, k, 0).unwrap();
        let sizes = labels.sizes();
        let max = *sizes.iter().max().unwrap();
        assert!(
            max <= 12 * (p / k).max(1),
            "shards={shards}: giant cluster of {max} (p/k = {})",
            p / k
        );
    }
}

/// Quality acceptance: sharded variance ratio within 5% of
/// single-thread on the Fig-5 cohort.
#[test]
fn sharded_quality_within_five_percent_of_single_thread() {
    let (s, c) = (10usize, 4usize);
    let ds = ContrastMapGenerator::new([14, 16, 12]).generate(s, c, 17);
    let g = LatticeGraph::from_mask(ds.mask());
    let k = (ds.p() / 10).max(2);

    let score = |labels: &Labels| -> f64 {
        let red = ClusterReduce::from_labels(labels);
        let vr = variance_ratio_per_voxel(&red.reduce(ds.data()), s, c);
        let per_voxel: Vec<f64> = labels
            .labels
            .iter()
            .map(|&cl| vr[cl as usize])
            .filter(|v| v.is_finite())
            .collect();
        median(&per_voxel)
    };

    let single =
        FastCluster::default().fit(ds.data(), &g, k, 1).unwrap();
    let vr_single = score(&single);
    assert!(vr_single.is_finite() && vr_single > 0.0);

    for shards in [2usize, 4] {
        let engine =
            ShardedFastCluster { n_shards: shards, ..Default::default() };
        let sharded = engine.fit(ds.data(), &g, k, 1).unwrap();
        assert_eq!(sharded.k, k);
        let vr_sharded = score(&sharded);
        let ratio = vr_sharded / vr_single;
        assert!(
            (ratio - 1.0).abs() <= 0.05,
            "shards={shards}: variance-ratio quality {ratio:.4} \
             outside the ±5% acceptance band \
             (single {vr_single:.4}, sharded {vr_sharded:.4})"
        );
    }
}

/// Determinism and the single-shard degenerate case.
#[test]
fn sharded_is_deterministic_and_one_shard_is_single_thread() {
    let ds = SyntheticCube::new([9, 9, 8], 4.0, 0.5).generate(3, 21);
    let g = LatticeGraph::from_mask(ds.mask());
    let k = 40;

    let engine =
        ShardedFastCluster { n_shards: 3, ..Default::default() };
    let a = engine.fit(ds.data(), &g, k, 5).unwrap();
    let b = engine.fit(ds.data(), &g, k, 5).unwrap();
    assert_eq!(a, b, "same seed must give identical partitions");

    let one =
        ShardedFastCluster { n_shards: 1, ..Default::default() };
    let via_sharded = one.fit(ds.data(), &g, k, 5).unwrap();
    let single = FastCluster::default().fit(ds.data(), &g, k, 5).unwrap();
    assert_eq!(via_sharded, single, "1 shard must equal single-thread");
}

/// The trace exposes per-shard round counts bounded by the Alg. 1
/// logarithmic guarantee applied shard-locally.
#[test]
fn sharded_trace_round_counts_stay_logarithmic() {
    let ds = SyntheticCube::new([12, 12, 10], 4.0, 0.5).generate(3, 31);
    let g = LatticeGraph::from_mask(ds.mask());
    let p = ds.p();
    let k = p / 10;
    let engine =
        ShardedFastCluster { n_shards: 4, ..Default::default() };
    let (labels, trace) =
        engine.fit_trace(ds.data(), &g, k, 0).unwrap();
    assert_eq!(labels.k, k);
    assert_eq!(trace.n_shards, 4);
    for (s, (&p_s, rounds)) in trace
        .shard_sizes
        .iter()
        .zip(trace.rounds_per_shard())
        .enumerate()
    {
        // per-shard target is >= its proportional share of k, so the
        // shard-local round bound is at most the global one
        let bound =
            ((p as f64 / k as f64).log2().ceil() as usize).max(1) + 2;
        assert!(
            rounds <= bound,
            "shard {s} (p_s={p_s}): {rounds} rounds > bound {bound}"
        );
    }
    assert!(trace.k_before_stitch >= k);
    assert_eq!(
        trace.stitch_merges,
        trace.k_before_stitch - labels.k
    );
}
