//! Chaos suite for the serve front-end (ADR-010): every fault the
//! [`ChaosProxy`] knows how to inject, replayed as a deterministic
//! schedule against both wires — the length-prefixed binary protocol
//! and the HTTP/JSON gateway. The contract under fire:
//!
//! * a **non-lossy** schedule (latency, frame splits at arbitrary
//!   byte boundaries, blackhole-then-recover) must still produce
//!   responses bit-identical to the offline apply-only path;
//! * a **lossy** schedule (mid-stream RST, half-close) may fail the
//!   request, but only as a clean typed error — never a panic, never
//!   a hang, never silently wrong bits;
//! * after any storm the server must still serve direct clients, and
//!   a slow-loris peer must not pin the connection budget: the idle
//!   deadline (`--idle-timeout-ms`) reaps quiet connections so the
//!   budget recovers without the client ever hanging up.
//!
//! The SIGTERM integration test rides along: `repro serve` must stop
//! accepting, drain, and exit 0 when signalled.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use fastclust::config::{
    DataConfig, EstimatorConfig, Method, ReduceConfig,
};
use fastclust::error::invalid;
use fastclust::model::{
    fit_model, load_model, save_model, FitOptions, FittedModel,
};
use fastclust::serve::protocol::{read_response, write_request};
use fastclust::serve::{
    Request, Response, ServeClient, ServeOptions, Server,
};
use fastclust::testkit::{ChaosProxy, Fault};
use fastclust::volume::{FeatureMatrix, MorphometryGenerator};

fn tmp(name: &str) -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_TARGET_TMPDIR"));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Fit + persist a small model; returns (path, loaded model, cohort
/// sample-major features) — the offline truth every surviving
/// response must reproduce bit-for-bit.
fn fixture(
    tag: &str,
) -> (PathBuf, Arc<FittedModel>, Arc<FeatureMatrix>) {
    let dc = DataConfig {
        dims: [8, 9, 7],
        n_samples: 24,
        seed: 17,
        ..Default::default()
    };
    let (ds, y) = MorphometryGenerator::new(dc.dims)
        .generate(dc.n_samples, dc.seed);
    let reduce = ReduceConfig {
        method: Method::Fast,
        ratio: 10,
        ..Default::default()
    };
    let est = EstimatorConfig {
        cv_folds: 3,
        max_iter: 60,
        ..Default::default()
    };
    let model =
        fit_model(&ds, &y, &reduce, &est, &dc, &FitOptions::default())
            .unwrap();
    let path = tmp(&format!("serve_chaos_{tag}.fcm"));
    save_model(&path, &model).unwrap();
    let loaded = Arc::new(load_model(&path).unwrap());
    let xs = Arc::new(ds.data().transpose());
    (path, loaded, xs)
}

fn block(xs: &FeatureMatrix) -> FeatureMatrix {
    xs.select_rows(&[0, 5])
}

/// One named single-fault schedule per proxy: with a one-entry menu
/// every connection (both directions) draws that fault, so each
/// schedule is exercised deterministically rather than hoped for.
fn schedules() -> Vec<(&'static str, Fault)> {
    vec![
        ("none", Fault::None),
        ("latency", Fault::Latency { ms: 10, jitter_ms: 20 }),
        ("split", Fault::Split { max_chunk: 7, delay_us: 200 }),
        (
            "blackhole",
            Fault::Blackhole { after_bytes: 1024, hold_ms: 300 },
        ),
        ("rst", Fault::Rst { after_bytes: 1500 }),
        ("halfclose", Fault::HalfClose { after_bytes: 1500 }),
    ]
}

/// One raw binary-protocol predict with read/write deadlines, so a
/// lossy schedule surfaces as an error instead of a hung test.
fn binary_predict(
    addr: SocketAddr,
    x: &FeatureMatrix,
    timeout: Duration,
) -> fastclust::error::Result<Vec<f32>> {
    let s = TcpStream::connect(addr)?;
    s.set_nodelay(true)?;
    s.set_read_timeout(Some(timeout))?;
    s.set_write_timeout(Some(timeout))?;
    let mut reader = BufReader::new(s.try_clone()?);
    let mut writer = BufWriter::new(s);
    write_request(
        &mut writer,
        &Request::Predict { model: String::new(), x: x.clone() },
    )?;
    writer.flush()?;
    match read_response(&mut reader)? {
        Response::Probabilities(p) => Ok(p),
        other => Err(invalid(format!("unexpected response {other:?}"))),
    }
}

/// One raw HTTP/1.1 exchange with deadlines; returns the status code
/// and body, or an I/O error when the schedule killed the exchange.
fn http_exchange(
    addr: SocketAddr,
    req: &str,
    timeout: Duration,
) -> std::io::Result<(u16, String)> {
    use std::io::{Error, ErrorKind};
    let mut s = TcpStream::connect(addr)?;
    s.set_nodelay(true)?;
    s.set_read_timeout(Some(timeout))?;
    s.set_write_timeout(Some(timeout))?;
    s.write_all(req.as_bytes())?;
    let mut reader = BufReader::new(s);
    let mut head = String::new();
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            return Err(Error::new(
                ErrorKind::UnexpectedEof,
                "closed mid-response",
            ));
        }
        if line == "\r\n" {
            break;
        }
        head.push_str(&line);
    }
    let status: u16 = head
        .lines()
        .next()
        .and_then(|l| l.split(' ').nth(1))
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| {
            Error::new(ErrorKind::InvalidData, "bad status line")
        })?;
    let clen: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length")
                .then(|| v.trim().parse().ok())?
        })
        .ok_or_else(|| {
            Error::new(ErrorKind::InvalidData, "no content-length")
        })?;
    let mut body = vec![0u8; clen];
    reader.read_exact(&mut body)?;
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}

fn predict_body(x: &FeatureMatrix) -> String {
    use std::fmt::Write as _;
    let mut out = String::from("{\"x\":[");
    for r in 0..x.rows {
        if r > 0 {
            out.push(',');
        }
        out.push('[');
        for c in 0..x.cols {
            if c > 0 {
                out.push(',');
            }
            let _ = write!(out, "{}", x.data[r * x.cols + c] as f64);
        }
        out.push(']');
    }
    out.push_str("]}");
    out
}

const ATTEMPT_TIMEOUT: Duration = Duration::from_secs(5);

#[test]
fn every_schedule_on_the_binary_wire() {
    let (path, model, xs) = fixture("bin");
    let mut opts = ServeOptions::new(&path);
    opts.workers = 2;
    opts.idle_timeout_ms = 1000;
    opts.log_path = Some(tmp("serve_chaos_bin.log"));
    let handle = Server::start(opts).unwrap();
    let addr = handle.addr();
    let x = block(&xs);
    let want = model.predict_proba(&x).unwrap();

    for (i, (name, fault)) in schedules().into_iter().enumerate() {
        let mut proxy =
            ChaosProxy::start(addr, 0xCA05_0000 + i as u64, vec![fault])
                .unwrap();
        for attempt in 0..2 {
            match binary_predict(proxy.addr(), &x, ATTEMPT_TIMEOUT) {
                Ok(p) => assert_eq!(
                    p, want,
                    "schedule {name} attempt {attempt}: served bits \
                     drifted under chaos"
                ),
                Err(e) => assert!(
                    fault.lossy(),
                    "schedule {name} attempt {attempt}: non-lossy \
                     schedule failed the request: {e}"
                ),
            }
        }
        proxy.stop();
        // the storm never takes the server down for direct clients
        let mut direct = ServeClient::connect(addr).unwrap();
        assert_eq!(
            direct.predict(&x).unwrap(),
            want,
            "schedule {name}: direct client broken after the storm"
        );
    }
    handle.shutdown().unwrap();
}

#[test]
fn every_schedule_on_the_http_wire() {
    let (path, model, xs) = fixture("http");
    let mut opts = ServeOptions::new(&path);
    opts.workers = 2;
    opts.http_port = Some(0);
    opts.idle_timeout_ms = 1000;
    opts.log_path = Some(tmp("serve_chaos_http.log"));
    let handle = Server::start(opts).unwrap();
    let http_addr = handle.http_addr().expect("gateway bound");
    let x = block(&xs);
    let want = model.predict_proba(&x).unwrap();
    let body = predict_body(&x);
    let req = format!(
        "POST /v1/predict HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );

    for (i, (name, fault)) in schedules().into_iter().enumerate() {
        let mut proxy = ChaosProxy::start(
            http_addr,
            0xCA05_1000 + i as u64,
            vec![fault],
        )
        .unwrap();
        for attempt in 0..2 {
            match http_exchange(proxy.addr(), &req, ATTEMPT_TIMEOUT) {
                Ok((200, text)) => {
                    let v = fastclust::json::parse(&text).unwrap();
                    let got: Vec<f32> = v
                        .get("proba")
                        .unwrap()
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|n| n.as_f64().unwrap() as f32)
                        .collect();
                    assert_eq!(
                        got, want,
                        "schedule {name} attempt {attempt}: HTTP \
                         bits drifted under chaos"
                    );
                }
                Ok((code, text)) => panic!(
                    "schedule {name} attempt {attempt}: unexpected \
                     HTTP {code}: {text}"
                ),
                Err(e) => assert!(
                    fault.lossy(),
                    "schedule {name} attempt {attempt}: non-lossy \
                     schedule failed the exchange: {e}"
                ),
            }
        }
        // liveness probe rides the same proxied wire
        match http_exchange(
            proxy.addr(),
            "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n",
            ATTEMPT_TIMEOUT,
        ) {
            Ok((code, _)) => assert_eq!(
                code, 200,
                "schedule {name}: healthz must answer 200"
            ),
            Err(e) => assert!(
                fault.lossy(),
                "schedule {name}: healthz failed on a non-lossy \
                 schedule: {e}"
            ),
        }
        proxy.stop();
        // the gateway still answers direct clients after the storm
        let (code, _) = http_exchange(
            http_addr,
            "GET /readyz HTTP/1.1\r\nHost: t\r\n\r\n",
            ATTEMPT_TIMEOUT,
        )
        .unwrap();
        assert_eq!(
            code, 200,
            "schedule {name}: readyz broken after the storm"
        );
    }
    handle.shutdown().unwrap();
}

#[test]
fn slow_loris_cannot_pin_the_connection_budget() {
    let (path, model, xs) = fixture("loris");
    let mut opts = ServeOptions::new(&path);
    opts.workers = 2;
    opts.max_connections = 4;
    opts.idle_timeout_ms = 400;
    opts.log_path = Some(tmp("serve_chaos_loris.log"));
    let handle = Server::start(opts).unwrap();
    let addr = handle.addr();
    let x = block(&xs);
    let want = model.predict_proba(&x).unwrap();

    // fill the whole budget with slow-loris peers: each dribbles a
    // few bytes of a frame through a (fault-free) chaos proxy, then
    // goes quiet while KEEPING its socket open
    let mut proxy =
        ChaosProxy::start(addr, 0xCA05_2000, vec![Fault::None])
            .unwrap();
    let mut lorises = Vec::new();
    for _ in 0..4 {
        let mut s = TcpStream::connect(proxy.addr()).unwrap();
        s.set_nodelay(true).unwrap();
        s.write_all(&[1, 0, 0]).unwrap();
        lorises.push(s);
    }

    // the budget recovers without any loris hanging up: the idle
    // deadline reaps them, so a full fleet of direct clients must
    // get served within a few reap ticks
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let ok = (0..4).all(|_| {
            binary_predict(addr, &x, Duration::from_secs(2))
                .map(|p| p == want)
                .unwrap_or(false)
        });
        if ok {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "budget never recovered from the slow-loris storm"
        );
        std::thread::sleep(Duration::from_millis(100));
    }
    let m = handle.metrics_json();
    assert!(
        m.get("idle_closed").unwrap().as_u64().unwrap() >= 4,
        "the reaper, not client hangups, must have freed the \
         budget: {m:?}"
    );
    drop(lorises);
    proxy.stop();
    handle.shutdown().unwrap();
}

/// SIGTERM on `repro serve`: stop accepting, drain in-flight work
/// within the existing shutdown deadline, exit 0.
#[cfg(unix)]
#[test]
fn sigterm_drains_and_exits_zero() {
    use std::process::{Command, Stdio};

    let (path, _, _) = fixture("sigterm");
    let mut child = Command::new(env!("CARGO_BIN_EXE_repro"))
        .arg("serve")
        .arg("--model")
        .arg(&path)
        .args(["--port", "0", "--workers", "2"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    // the CLI installs the handler before it prints this line
    let stdout = child.stdout.take().unwrap();
    let mut serving = false;
    for line in BufReader::new(stdout).lines() {
        if line.unwrap().contains("serving on") {
            serving = true;
            break;
        }
    }
    assert!(serving, "server never reported serving");

    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    const SIGTERM: i32 = 15;
    assert_eq!(
        unsafe { kill(child.id() as i32, SIGTERM) },
        0,
        "kill(2) failed"
    );

    let deadline = Instant::now() + Duration::from_secs(10);
    let status = loop {
        if let Some(st) = child.try_wait().unwrap() {
            break st;
        }
        assert!(
            Instant::now() < deadline,
            "serve did not exit within 10s of SIGTERM"
        );
        std::thread::sleep(Duration::from_millis(20));
    };
    assert_eq!(
        status.code(),
        Some(0),
        "SIGTERM drain must exit 0, got {status:?}"
    );
}
