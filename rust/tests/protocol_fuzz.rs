//! Fuzz smoke for the wire protocol (ADR-004 frames + the ADR-006
//! ASSIGN/PARTIAL/ACK/RETRY extension + the ADR-009 FETCH/DATA
//! range-serving frames + the ADR-007 HTTP head parser and lazy
//! JSON scanners): every decoder entry point must
//! survive truncation, bit-flips, garbage and hostile length claims
//! with a clean `Err` (or `Ok(None)` / `Incomplete` / `Bad`) — never
//! a panic, hang or unbounded allocation. Hand-rolled sweeps over
//! the crate's own seeded [`Rng`]; failures print the seed / offset
//! for replay.

use std::io::Cursor;

use fastclust::json::{self, Value};
use fastclust::rng::Rng;
use fastclust::serve::http::{self, Parse};
use fastclust::serve::protocol::{
    read_dist_frame, read_request, read_response, write_dist_frame,
    write_request, write_response, DistFrame, Request, Response,
    ACK_DONE, ACK_HEARTBEAT,
};
use fastclust::volume::FeatureMatrix;

fn matrix(rows: usize, cols: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(rows, cols);
    rng.fill_normal(&mut m.data);
    m
}

/// A representative valid frame of every kind, encoded.
fn valid_dist_frames() -> Vec<Vec<u8>> {
    let frames = vec![
        DistFrame::Assign { job: 7, payload: vec![1, 2, 3, 4, 5] },
        DistFrame::Partial {
            job: 7,
            seq: 2,
            payload: matrix(3, 4, 9)
                .data
                .iter()
                .flat_map(|f| f.to_le_bytes())
                .collect(),
        },
        DistFrame::Ack { job: 7, kind: ACK_DONE, info: 3 },
        DistFrame::Ack { job: 0, kind: ACK_HEARTBEAT, info: 0 },
        DistFrame::Retry { job: 9, reason: "busy".into() },
        // ADR-009 range serving: a shard-data request and its block
        DistFrame::Fetch { job: 3, col0: 8, count: 4 },
        DistFrame::Data {
            job: 3,
            col0: 8,
            payload: matrix(5, 4, 13)
                .data
                .iter()
                .flat_map(|f| f.to_le_bytes())
                .collect(),
        },
    ];
    frames
        .iter()
        .map(|f| {
            let mut buf = Vec::new();
            write_dist_frame(&mut buf, f).unwrap();
            buf
        })
        .collect()
}

fn valid_serve_frames() -> Vec<Vec<u8>> {
    let x = matrix(2, 5, 11);
    let mut out = Vec::new();
    for rq in [
        Request::ModelInfo { model: "m".into() },
        Request::Compress { model: String::new(), x: x.clone() },
        Request::Predict { model: String::new(), x: x.clone() },
    ] {
        let mut buf = Vec::new();
        write_request(&mut buf, &rq).unwrap();
        out.push(buf);
    }
    for rs in [
        Response::Info("{\"k\":3}".into()),
        Response::Probabilities(vec![0.25, 0.5]),
        Response::Compressed(x),
        Response::Error("nope".into()),
        Response::Shed("server at connection capacity".into()),
    ] {
        let mut buf = Vec::new();
        write_response(&mut buf, &rs).unwrap();
        out.push(buf);
    }
    out
}

/// Feed `bytes` to every decoder; each must return without panicking.
/// (A short read is `Err` or `Ok(None)`; we only assert no panic and
/// no runaway allocation — correctness of `Ok` values is covered by
/// the unit roundtrip tests.)
fn decoders_survive(bytes: &[u8]) {
    let _ = read_dist_frame(&mut Cursor::new(bytes));
    let _ = read_request(&mut Cursor::new(bytes));
    let _ = read_response(&mut Cursor::new(bytes));
}

/// Every strict prefix of a valid frame decodes to a clean error
/// (or EOF), never a panic or a hang on the in-memory reader.
#[test]
fn fuzz_truncation_sweep() {
    for (i, frame) in valid_dist_frames()
        .into_iter()
        .chain(valid_serve_frames())
        .enumerate()
    {
        for cut in 0..frame.len() {
            decoders_survive(&frame[..cut]);
        }
        // the full frame must decode through its own reader
        assert!(
            read_dist_frame(&mut Cursor::new(&frame)).is_ok()
                || read_request(&mut Cursor::new(&frame)).is_ok()
                || read_response(&mut Cursor::new(&frame)).is_ok(),
            "frame {i}: no decoder accepts its own valid encoding"
        );
    }
}

/// Single-byte corruptions: flip each byte of each valid frame to a
/// few values; decoding must never panic, and dist frames with a
/// corrupted payload must not sneak through the checksum.
#[test]
fn fuzz_bitflip_sweep() {
    for frame in valid_dist_frames() {
        for off in 0..frame.len() {
            for flip in [0x01u8, 0x80, 0xFF] {
                let mut bad = frame.clone();
                bad[off] ^= flip;
                decoders_survive(&bad);
            }
        }
    }
    for frame in valid_serve_frames() {
        // serve frames are larger; stride the offsets
        for off in (0..frame.len()).step_by(3) {
            let mut bad = frame.clone();
            bad[off] ^= 0xFF;
            decoders_survive(&bad);
        }
    }
}

/// Pure seeded garbage of many lengths.
#[test]
fn fuzz_garbage_streams() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0xF422);
        let len = rng.below(600);
        let bytes: Vec<u8> =
            (0..len).map(|_| rng.below(256) as u8).collect();
        decoders_survive(&bytes);
    }
}

/// Hostile length claims: a tiny buffer whose header promises a huge
/// body must fail fast without attempting the allocation (the reader
/// is capped by what the stream actually holds).
#[test]
fn fuzz_oversized_length_claims() {
    for opcode in [1u8, 2, 3, 4, 5, 6, 7, 8, 9, 0xAA, 0xFF] {
        for claim in [
            (1u32 << 28) - 1, // just under MAX_BODY_BYTES
            1 << 28,
            u32::MAX,
        ] {
            let mut bytes = vec![opcode];
            bytes.extend_from_slice(&claim.to_le_bytes());
            bytes.extend_from_slice(&[0u8; 64]); // far short of claim
            let t0 = std::time::Instant::now();
            decoders_survive(&bytes);
            assert!(
                t0.elapsed() < std::time::Duration::from_secs(5),
                "opcode {opcode} claim {claim}: decoder stalled"
            );
        }
    }
}

// ------------------------------------------------ HTTP head parser

/// Representative valid requests for the gateway's supported subset.
fn valid_http_requests() -> Vec<Vec<u8>> {
    let body = "{\"x\":[[1,2,3]]}";
    vec![
        b"GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n".to_vec(),
        format!(
            "POST /v1/predict HTTP/1.1\r\nContent-Length: {}\r\n\
             \r\n{}",
            body.len(),
            body
        )
        .into_bytes(),
        b"GET /v1/models HTTP/1.0\r\nConnection: keep-alive\r\n\r\n"
            .to_vec(),
        format!(
            "POST /v1/compress HTTP/1.1\r\nConnection: close\r\n\
             Content-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .into_bytes(),
    ]
}

/// Every strict prefix of a valid request is `Incomplete` (or at
/// worst `Bad`), never `Ok` and never a panic; the full buffer
/// parses `Ok` and reports `consumed == len`.
#[test]
fn http_fuzz_truncation_sweep() {
    for (i, req) in valid_http_requests().into_iter().enumerate() {
        for cut in 0..req.len() {
            match http::parse_request(&req[..cut]) {
                Parse::Ok(r) => panic!(
                    "request {i} cut {cut}: accepted a strict \
                     prefix as {r:?}"
                ),
                Parse::Incomplete | Parse::Bad { .. } => {}
            }
        }
        match http::parse_request(&req) {
            Parse::Ok(r) => {
                assert_eq!(
                    r.consumed,
                    req.len(),
                    "request {i}: wrong drain length"
                );
                assert!(r.path.starts_with('/'));
            }
            other => {
                panic!("request {i}: valid request got {other:?}")
            }
        }
    }
}

/// Two pipelined requests in one buffer: parse, drain `consumed`,
/// parse again — both must come out whole and in order.
#[test]
fn http_fuzz_pipelined_requests() {
    let reqs = valid_http_requests();
    let mut buf = reqs[0].clone();
    buf.extend_from_slice(&reqs[1]);
    let first = match http::parse_request(&buf) {
        Parse::Ok(r) => r,
        other => panic!("first request: {other:?}"),
    };
    assert_eq!(first.path, "/metrics");
    match http::parse_request(&buf[first.consumed..]) {
        Parse::Ok(r) => {
            assert_eq!(r.path, "/v1/predict");
            assert_eq!(r.body, b"{\"x\":[[1,2,3]]}");
        }
        other => panic!("second request: {other:?}"),
    }
}

/// Hostile heads must be rejected with the documented statuses —
/// before any body buffering — and garbage must never panic.
#[test]
fn http_fuzz_hostile_heads() {
    let expect_bad = |req: &str, want: u16| {
        match http::parse_request(req.as_bytes()) {
            Parse::Bad { status, .. } => assert_eq!(
                status, want,
                "wrong status for {req:?}"
            ),
            other => panic!("{req:?}: expected Bad, got {other:?}"),
        }
    };
    // Content-Length over the 64 MiB cap → 413 with no buffering
    expect_bad(
        "POST /v1/predict HTTP/1.1\r\n\
         Content-Length: 999999999999\r\n\r\n",
        413,
    );
    expect_bad(
        "POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n",
        400,
    );
    expect_bad(
        "POST / HTTP/1.1\r\nContent-Length: 4\r\n\
         Content-Length: 5\r\n\r\nabcde",
        400,
    );
    expect_bad(
        "POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
        501,
    );
    expect_bad("GET / HTTP/2\r\n\r\n", 400);
    expect_bad("GET\r\n\r\n", 400);
    expect_bad("GET nothing HTTP/1.1\r\n\r\n", 400);
    expect_bad("GET / HTTP/1.1\r\nno-colon-here\r\n\r\n", 400);
    // oversized head without a terminator → 431
    let huge = format!(
        "GET / HTTP/1.1\r\nX-Pad: {}\r\n",
        "a".repeat(http::MAX_HEAD_BYTES)
    );
    expect_bad(&huge, 431);
    // non-UTF-8 head bytes → 400
    let mut bad = b"GET /\xFF\xFE HTTP/1.1\r\n\r\n".to_vec();
    match http::parse_request(&bad) {
        Parse::Bad { status, .. } => assert_eq!(status, 400),
        other => panic!("non-UTF-8 head: {other:?}"),
    }
    // seeded garbage of many lengths: any outcome but a panic
    let mut rng = Rng::new(0xC0FFEE);
    for _ in 0..60 {
        bad.clear();
        let len = rng.below(400);
        bad.extend((0..len).map(|_| rng.below(256) as u8));
        let _ = http::parse_request(&bad);
        // and the same bytes behind a plausible request line
        let mut framed = b"POST /v1/predict HTTP/1.1\r\n".to_vec();
        framed.extend_from_slice(&bad);
        let _ = http::parse_request(&framed);
    }
}

// -------------------------------------------- lazy JSON scanners

/// Deterministically grow a random JSON document and remember every
/// leaf path; used to cross-check the lazy scanners below.
fn gen_value(rng: &mut Rng, depth: usize) -> Value {
    let pick =
        if depth >= 3 { rng.below(4) } else { rng.below(6) };
    match pick {
        0 => Value::Null,
        1 => Value::Bool(rng.below(2) == 1),
        // quarters are exact in f64 and survive the shortest
        // round-trip printer unchanged
        2 => Value::Num(rng.below(4000) as f64 / 4.0 - 500.0),
        3 => Value::Str(match rng.below(3) {
            0 => format!("plain{}", rng.below(100)),
            1 => "esc \"quote\" \\slash\\ \n tab\t".to_string(),
            _ => "unicode: λ→∎ ünïcode".to_string(),
        }),
        4 => Value::Arr(
            (0..rng.below(3))
                .map(|_| gen_value(rng, depth + 1))
                .collect(),
        ),
        _ => Value::Obj(
            (0..1 + rng.below(3))
                .map(|i| {
                    (format!("k{i}"), gen_value(rng, depth + 1))
                })
                .collect(),
        ),
    }
}

/// Collect `(path, leaf)` pairs for every object-reachable node.
fn walk<'a>(
    v: &'a Value,
    prefix: &mut Vec<&'a str>,
    out: &mut Vec<(Vec<String>, &'a Value)>,
) {
    out.push((
        prefix.iter().map(|s| s.to_string()).collect(),
        v,
    ));
    if let Value::Obj(pairs) = v {
        for (k, child) in pairs {
            prefix.push(k);
            walk(child, prefix, out);
            prefix.pop();
        }
    }
}

/// Property sweep: on seeded random documents (compact and pretty),
/// `scan_path` + the typed wrappers agree exactly with the tree
/// parser at every object path.
#[test]
fn json_fuzz_scanners_agree_with_parser() {
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed ^ 0x1A2B);
        let doc = Value::Obj(
            (0..2 + rng.below(3))
                .map(|i| {
                    (format!("k{i}"), gen_value(&mut rng, 1))
                })
                .collect(),
        );
        let mut sites = Vec::new();
        walk(&doc, &mut Vec::new(), &mut sites);
        for text in [doc.to_string(), doc.to_string_pretty()] {
            for (path, want) in &sites {
                let steps: Vec<&str> =
                    path.iter().map(|s| s.as_str()).collect();
                let raw = json::scan_path(&text, &steps)
                    .unwrap()
                    .unwrap_or_else(|| {
                        panic!("seed {seed}: lost path {path:?}")
                    });
                let got = json::parse(raw).unwrap();
                assert_eq!(
                    &got, *want,
                    "seed {seed} path {path:?}: scanner slice \
                     disagrees with the tree parser"
                );
                match want {
                    Value::Str(s) => assert_eq!(
                        json::scan_str(&text, &steps)
                            .unwrap()
                            .as_deref(),
                        Some(s.as_str())
                    ),
                    Value::Num(n) => assert_eq!(
                        json::scan_f64(&text, &steps).unwrap(),
                        Some(*n)
                    ),
                    _ => {}
                }
            }
            // absent keys are None, not an error
            assert_eq!(
                json::scan_path(&text, &["k0", "no_such_key_zz"])
                    .ok()
                    .flatten(),
                None
            );
        }
    }
}

/// The scanners never panic on garbage: truncations of a valid
/// document and pure seeded noise both come back as `Err`/`None`.
#[test]
fn json_fuzz_scanners_survive_garbage() {
    let doc = "{\"a\":{\"b\":[1,2,{\"c\":\"d\"}],\"e\":1.5}}";
    for cut in 0..doc.len() {
        let _ = json::scan_path(&doc[..cut], &["a", "b"]);
        let _ = json::scan_str(&doc[..cut], &["a"]);
        let _ = json::scan_f64(&doc[..cut], &["a", "e"]);
        let _ = json::scan_f32_matrix(&doc[..cut], &["a"]);
    }
    let mut rng = Rng::new(0xDEAD);
    for _ in 0..60 {
        let len = rng.below(300);
        let noise: Vec<u8> = (0..len)
            .map(|_| (32 + rng.below(95)) as u8)
            .collect();
        let text = String::from_utf8(noise).unwrap();
        let _ = json::scan_path(&text, &["x"]);
        let _ = json::scan_f32_matrix(&text, &["x"]);
    }
    // deep nesting is a bounded error for scanners too
    let deep = "{\"x\":".repeat(4_000) + "1";
    assert!(json::scan_path(&deep, &["x", "x", "x"]).is_err());
}

// -------------------------------------- mapped .fcm loader (ADR-008)

/// The committed golden model, as bytes to mutate.
fn fcm_fixture_bytes() -> Vec<u8> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/tiny.fcm");
    std::fs::read(path).unwrap()
}

/// Write `bytes` under a unique name and run them through the full
/// lazy path: `open_model` (header-eager) then `to_fitted` (which
/// checksums and decodes every section). Returns the combined
/// result; the caller asserts on it. mmap needs a real file, so the
/// sweep goes through disk.
fn open_fully(
    dir: &std::path::Path,
    name: &str,
    bytes: &[u8],
) -> Result<(), String> {
    let path = dir.join(name);
    std::fs::write(&path, bytes).unwrap();
    fastclust::model::open_model(&path)
        .and_then(|m| m.to_fitted())
        .map(|_| ())
        .map_err(|e| e.to_string())
}

fn fcm_scratch(tag: &str) -> std::path::PathBuf {
    let dir = std::path::PathBuf::from(env!("CARGO_TARGET_TMPDIR"))
        .join(format!("fcm_fuzz_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Every strict prefix of a valid `.fcm` must come back as a clean
/// error from the mapped loader — never a panic, OOB read or
/// partial model.
#[test]
fn fcm_mmap_truncation_sweep() {
    let bytes = fcm_fixture_bytes();
    let dir = fcm_scratch("trunc");
    for cut in 0..bytes.len() {
        assert!(
            open_fully(&dir, "t.fcm", &bytes[..cut]).is_err(),
            "cut {cut}: mapped loader accepted a truncated file"
        );
    }
    // and the untruncated file decodes (the sweep is honest)
    open_fully(&dir, "t.fcm", &bytes).unwrap();
}

/// Single-byte corruption anywhere in the artifact must surface as
/// an error once every section is touched: magic and structure are
/// checked by the index walk, payload bytes by the per-section
/// CRCs on first touch.
#[test]
fn fcm_mmap_bitflip_sweep() {
    let bytes = fcm_fixture_bytes();
    let dir = fcm_scratch("flip");
    for off in 0..bytes.len() {
        for flip in [0x01u8, 0x80, 0xFF] {
            let mut bad = bytes.clone();
            bad[off] ^= flip;
            assert!(
                open_fully(&dir, "f.fcm", &bad).is_err(),
                "offset {off} flip {flip:#04x}: corruption \
                 survived the mapped load"
            );
        }
    }
}

/// Hostile section length claims: a small file whose section header
/// promises gigabytes must fail fast in the index walk — no
/// allocation, no checksum pass over memory that does not exist.
#[test]
fn fcm_mmap_oversized_length_claims() {
    let dir = fcm_scratch("claims");
    for claim in [
        (1u64 << 30) + 1, // just over MAX_SECTION_BYTES
        1u64 << 40,
        u64::MAX,
        u64::MAX - 3, // start + len + 4 must not wrap
    ] {
        let mut bytes = b"FCMODEL1".to_vec();
        bytes.extend_from_slice(b"HEAD");
        bytes.extend_from_slice(&claim.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 64]); // far short of claim
        let t0 = std::time::Instant::now();
        let err =
            open_fully(&dir, "c.fcm", &bytes).unwrap_err();
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(5),
            "claim {claim}: mapped loader stalled"
        );
        assert!(
            err.contains("corrupt") || err.contains("truncated"),
            "claim {claim}: unexpected error: {err}"
        );
    }
    // an in-bounds claim that overruns the actual file is a clean
    // truncation error too
    let mut bytes = b"FCMODEL1".to_vec();
    bytes.extend_from_slice(b"HEAD");
    bytes.extend_from_slice(&4096u64.to_le_bytes());
    bytes.extend_from_slice(&[0u8; 32]);
    assert!(open_fully(&dir, "c.fcm", &bytes)
        .unwrap_err()
        .contains("truncated"));
}

// ---------------------------------------- .fcj job journal (ADR-010)

use fastclust::coordinator::journal::{JOURNAL_MAGIC, MAX_RECORD_BYTES};
use fastclust::coordinator::{
    decode_journal, decode_record, JournalHeader, JournalRecord,
    JournalWriter,
};

fn fcj_header() -> JournalHeader {
    JournalHeader {
        data_crc: 0x1234_5678,
        data_len: 4096,
        meta_crc: 0x9ABC_DEF0,
        config_crc: 77,
        lanes: 6,
        n: 24,
    }
}

fn fcj_records() -> Vec<JournalRecord> {
    vec![
        JournalRecord {
            job_id: 0,
            payload_crc: 11,
            partials: vec![(0, vec![1, 2, 3, 4]), (1, vec![5])],
        },
        JournalRecord {
            job_id: 3,
            payload_crc: 22,
            partials: vec![(0, b"partial-bytes".to_vec())],
        },
        JournalRecord { job_id: 9, payload_crc: 33, partials: vec![] },
    ]
}

/// A valid journal image: header plus [`fcj_records`], via the real
/// writer so the sweep covers the exact on-disk envelope.
fn fcj_fixture_bytes(tag: &str) -> Vec<u8> {
    let dir = std::path::PathBuf::from(env!("CARGO_TARGET_TMPDIR"));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("fcj_fuzz_{tag}.fcj"));
    let mut w = JournalWriter::create(&path, &fcj_header()).unwrap();
    for r in fcj_records() {
        w.append(&r).unwrap();
    }
    drop(w);
    std::fs::read(&path).unwrap()
}

/// Truncation at every byte boundary. Before the header envelope
/// ends the journal is unusable (`Err`); from there on, salvage
/// must return exactly a prefix of the true records, flag any torn
/// tail, and never panic — a crash mid-append is the designed case.
#[test]
fn fcj_fuzz_truncation_sweep() {
    let bytes = fcj_fixture_bytes("trunc");
    let want = fcj_records();
    // locate the end of the header envelope: magic + len|body|crc
    let hlen = u32::from_le_bytes(
        bytes[8..12].try_into().unwrap(),
    ) as usize;
    let header_end = 8 + 4 + hlen + 4;
    for cut in 0..bytes.len() {
        match decode_journal(&bytes[..cut]) {
            Err(_) => assert!(
                cut < header_end,
                "cut {cut}: intact header rejected"
            ),
            Ok((h, recs, valid, torn)) => {
                assert!(
                    cut >= header_end,
                    "cut {cut}: accepted a torn header"
                );
                assert_eq!(h, fcj_header());
                assert_eq!(
                    recs,
                    want[..recs.len()],
                    "cut {cut}: salvage is not a prefix"
                );
                assert!(valid <= cut, "cut {cut}: prefix overruns");
                // anything between the last intact record and the
                // cut is a torn tail and must be reported as such
                assert_eq!(torn, valid < cut, "cut {cut}");
            }
        }
    }
    let (_, recs, valid, torn) = decode_journal(&bytes).unwrap();
    assert_eq!(recs, want);
    assert_eq!(valid, bytes.len());
    assert!(!torn);
}

/// Single-byte corruption anywhere in the image: decoding must never
/// panic, and whatever survives salvage must still be a prefix of
/// the true records — a flipped byte can tear the journal but never
/// alter a record past its checksum.
#[test]
fn fcj_fuzz_bitflip_sweep() {
    let bytes = fcj_fixture_bytes("flip");
    let want = fcj_records();
    for off in 0..bytes.len() {
        for flip in [0x01u8, 0x80, 0xFF] {
            let mut bad = bytes.clone();
            bad[off] ^= flip;
            if let Ok((_, recs, _, _)) = decode_journal(&bad) {
                assert_eq!(
                    recs,
                    want[..recs.len()],
                    "offset {off} flip {flip:#04x}: corruption \
                     replayed as a record"
                );
            }
        }
    }
}

/// Garbage: pure noise must be a clean error, noise appended after a
/// valid journal must salvage every real record and flag the tail,
/// and the strict record decoder must reject noise outright.
#[test]
fn fcj_fuzz_garbage_records() {
    let mut rng = Rng::new(0xFC10);
    for _ in 0..40 {
        let len = rng.below(400);
        let noise: Vec<u8> =
            (0..len).map(|_| rng.below(256) as u8).collect();
        assert!(
            decode_journal(&noise).is_err()
                || noise[..8.min(noise.len())] == JOURNAL_MAGIC[..],
            "garbage accepted as a journal"
        );
        let _ = decode_record(&noise);
    }
    let bytes = fcj_fixture_bytes("tail");
    for junk_len in [1usize, 3, 8, 64] {
        let mut bad = bytes.clone();
        bad.extend((0..junk_len).map(|_| rng.below(256) as u8));
        let (_, recs, valid, torn) = decode_journal(&bad).unwrap();
        assert_eq!(recs, fcj_records());
        assert!(torn, "junk of {junk_len} bytes not flagged");
        assert_eq!(valid, bytes.len());
    }
}

/// Hostile length claims: headers or records promising up to 4 GiB
/// in a tiny buffer must fail fast — no allocation sized by the
/// claim, no stall. A huge claim *after* valid records only tears
/// the tail.
#[test]
fn fcj_fuzz_oversized_length_claims() {
    for claim in [
        MAX_RECORD_BYTES as u32,
        (MAX_RECORD_BYTES as u32) + 1,
        u32::MAX,
    ] {
        // as the header envelope
        let mut b = JOURNAL_MAGIC.to_vec();
        b.extend_from_slice(&claim.to_le_bytes());
        b.extend_from_slice(&[0u8; 64]);
        let t0 = std::time::Instant::now();
        assert!(decode_journal(&b).is_err());
        // as a bare record envelope
        let mut r = claim.to_le_bytes().to_vec();
        r.extend_from_slice(&[0u8; 64]);
        assert!(decode_record(&r).is_err());
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(5),
            "claim {claim}: journal decoder stalled"
        );
        // appended after real records: salvage keeps them all
        let mut tail = fcj_fixture_bytes("claims");
        let full = tail.len();
        tail.extend_from_slice(&claim.to_le_bytes());
        tail.extend_from_slice(&[0u8; 16]);
        let (_, recs, valid, torn) = decode_journal(&tail).unwrap();
        assert_eq!(recs, fcj_records());
        assert_eq!(valid, full);
        assert!(torn);
    }
}

/// Concatenated valid frames with garbage between them: the dist
/// reader must decode the first frame and fail (not panic) on the
/// garbage that follows.
#[test]
fn fuzz_frame_then_garbage() {
    let mut rng = Rng::new(0xBADF00D);
    for frame in valid_dist_frames() {
        let mut stream = frame.clone();
        let junk = 1 + rng.below(32);
        stream.extend((0..junk).map(|_| rng.below(256) as u8));
        let mut cur = Cursor::new(&stream);
        let first = read_dist_frame(&mut cur).unwrap();
        assert!(first.is_some(), "lost the leading valid frame");
        // whatever follows: error or EOF, never a panic
        let _ = read_dist_frame(&mut cur);
    }
}
