//! Fuzz smoke for the wire protocol (ADR-004 frames + the ADR-006
//! ASSIGN/PARTIAL/ACK/RETRY extension): every decoder entry point
//! must survive truncation, bit-flips, garbage and hostile length
//! claims with a clean `Err` (or `Ok(None)` at EOF) — never a panic,
//! hang or unbounded allocation. Hand-rolled sweeps over the crate's
//! own seeded [`Rng`]; failures print the seed / offset for replay.

use std::io::Cursor;

use fastclust::rng::Rng;
use fastclust::serve::protocol::{
    read_dist_frame, read_request, read_response, write_dist_frame,
    write_request, write_response, DistFrame, Request, Response,
    ACK_DONE, ACK_HEARTBEAT,
};
use fastclust::volume::FeatureMatrix;

fn matrix(rows: usize, cols: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(rows, cols);
    rng.fill_normal(&mut m.data);
    m
}

/// A representative valid frame of every kind, encoded.
fn valid_dist_frames() -> Vec<Vec<u8>> {
    let frames = vec![
        DistFrame::Assign { job: 7, payload: vec![1, 2, 3, 4, 5] },
        DistFrame::Partial {
            job: 7,
            seq: 2,
            payload: matrix(3, 4, 9)
                .data
                .iter()
                .flat_map(|f| f.to_le_bytes())
                .collect(),
        },
        DistFrame::Ack { job: 7, kind: ACK_DONE, info: 3 },
        DistFrame::Ack { job: 0, kind: ACK_HEARTBEAT, info: 0 },
        DistFrame::Retry { job: 9, reason: "busy".into() },
    ];
    frames
        .iter()
        .map(|f| {
            let mut buf = Vec::new();
            write_dist_frame(&mut buf, f).unwrap();
            buf
        })
        .collect()
}

fn valid_serve_frames() -> Vec<Vec<u8>> {
    let x = matrix(2, 5, 11);
    let mut out = Vec::new();
    for rq in [
        Request::ModelInfo { model: "m".into() },
        Request::Compress { model: String::new(), x: x.clone() },
        Request::Predict { model: String::new(), x: x.clone() },
    ] {
        let mut buf = Vec::new();
        write_request(&mut buf, &rq).unwrap();
        out.push(buf);
    }
    for rs in [
        Response::Info("{\"k\":3}".into()),
        Response::Probabilities(vec![0.25, 0.5]),
        Response::Compressed(x),
        Response::Error("nope".into()),
    ] {
        let mut buf = Vec::new();
        write_response(&mut buf, &rs).unwrap();
        out.push(buf);
    }
    out
}

/// Feed `bytes` to every decoder; each must return without panicking.
/// (A short read is `Err` or `Ok(None)`; we only assert no panic and
/// no runaway allocation — correctness of `Ok` values is covered by
/// the unit roundtrip tests.)
fn decoders_survive(bytes: &[u8]) {
    let _ = read_dist_frame(&mut Cursor::new(bytes));
    let _ = read_request(&mut Cursor::new(bytes));
    let _ = read_response(&mut Cursor::new(bytes));
}

/// Every strict prefix of a valid frame decodes to a clean error
/// (or EOF), never a panic or a hang on the in-memory reader.
#[test]
fn fuzz_truncation_sweep() {
    for (i, frame) in valid_dist_frames()
        .into_iter()
        .chain(valid_serve_frames())
        .enumerate()
    {
        for cut in 0..frame.len() {
            decoders_survive(&frame[..cut]);
        }
        // the full frame must decode through its own reader
        assert!(
            read_dist_frame(&mut Cursor::new(&frame)).is_ok()
                || read_request(&mut Cursor::new(&frame)).is_ok()
                || read_response(&mut Cursor::new(&frame)).is_ok(),
            "frame {i}: no decoder accepts its own valid encoding"
        );
    }
}

/// Single-byte corruptions: flip each byte of each valid frame to a
/// few values; decoding must never panic, and dist frames with a
/// corrupted payload must not sneak through the checksum.
#[test]
fn fuzz_bitflip_sweep() {
    for frame in valid_dist_frames() {
        for off in 0..frame.len() {
            for flip in [0x01u8, 0x80, 0xFF] {
                let mut bad = frame.clone();
                bad[off] ^= flip;
                decoders_survive(&bad);
            }
        }
    }
    for frame in valid_serve_frames() {
        // serve frames are larger; stride the offsets
        for off in (0..frame.len()).step_by(3) {
            let mut bad = frame.clone();
            bad[off] ^= 0xFF;
            decoders_survive(&bad);
        }
    }
}

/// Pure seeded garbage of many lengths.
#[test]
fn fuzz_garbage_streams() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0xF422);
        let len = rng.below(600);
        let bytes: Vec<u8> =
            (0..len).map(|_| rng.below(256) as u8).collect();
        decoders_survive(&bytes);
    }
}

/// Hostile length claims: a tiny buffer whose header promises a huge
/// body must fail fast without attempting the allocation (the reader
/// is capped by what the stream actually holds).
#[test]
fn fuzz_oversized_length_claims() {
    for opcode in [1u8, 2, 3, 4, 5, 6, 7, 0xAA, 0xFF] {
        for claim in [
            (1u32 << 28) - 1, // just under MAX_BODY_BYTES
            1 << 28,
            u32::MAX,
        ] {
            let mut bytes = vec![opcode];
            bytes.extend_from_slice(&claim.to_le_bytes());
            bytes.extend_from_slice(&[0u8; 64]); // far short of claim
            let t0 = std::time::Instant::now();
            decoders_survive(&bytes);
            assert!(
                t0.elapsed() < std::time::Duration::from_secs(5),
                "opcode {opcode} claim {claim}: decoder stalled"
            );
        }
    }
}

/// Concatenated valid frames with garbage between them: the dist
/// reader must decode the first frame and fail (not panic) on the
/// garbage that follows.
#[test]
fn fuzz_frame_then_garbage() {
    let mut rng = Rng::new(0xBADF00D);
    for frame in valid_dist_frames() {
        let mut stream = frame.clone();
        let junk = 1 + rng.below(32);
        stream.extend((0..junk).map(|_| rng.below(256) as u8));
        let mut cur = Cursor::new(&stream);
        let first = read_dist_frame(&mut cur).unwrap();
        assert!(first.is_some(), "lost the leading valid frame");
        // whatever follows: error or EOF, never a panic
        let _ = read_dist_frame(&mut cur);
    }
}
