//! Property test for the fitted-model artifact (ADR-004):
//! fit → save → load → predict is **bit-identical** to
//! fit → predict in-memory, across the FastCluster, Ward and sharded
//! clustering engines and both logistic-regression backends (batch
//! and SGD). Also pins the artifact against the reference pipeline:
//! for the batch backend, the persisted fold accuracies equal
//! `run_decoding_pipeline`'s exactly.

use std::path::PathBuf;

use fastclust::config::{
    DataConfig, EstimatorConfig, Method, ReduceConfig,
};
use fastclust::coordinator::run_decoding_pipeline;
use fastclust::model::{
    fit_model, load_model, open_model, read_fcm_header, save_model,
    FitOptions, FittedModel,
};
use fastclust::volume::{MaskedDataset, MorphometryGenerator};

fn cohort() -> (MaskedDataset, Vec<u8>, DataConfig) {
    let dc = DataConfig {
        dims: [10, 11, 9],
        n_samples: 36,
        seed: 17,
        ..Default::default()
    };
    let (ds, y) =
        MorphometryGenerator::new(dc.dims).generate(dc.n_samples, dc.seed);
    (ds, y, dc)
}

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("fastclust_model_prop");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}.fcm"))
}

fn assert_bit_identical(a: &FittedModel, b: &FittedModel) {
    assert_eq!(a.header, b.header);
    assert_eq!(a.mask_dims, b.mask_dims);
    assert_eq!(a.voxels, b.voxels);
    assert_eq!(a.reduction, b.reduction);
    assert_eq!(a.folds.len(), b.folds.len());
    for (fa, fb) in a.folds.iter().zip(&b.folds) {
        assert_eq!(fa.test, fb.test);
        // f64/f32 compared through raw bits: NaN-proof and exact
        assert_eq!(
            fa.accuracy.to_bits(),
            fb.accuracy.to_bits(),
            "fold accuracy drifted through the artifact"
        );
        assert_eq!(fa.fit.b.to_bits(), fb.fit.b.to_bits());
        assert_eq!(fa.fit.w.len(), fb.fit.w.len());
        for (wa, wb) in fa.fit.w.iter().zip(&fb.fit.w) {
            assert_eq!(wa.to_bits(), wb.to_bits());
        }
        assert_eq!(fa.fit.loss.to_bits(), fb.fit.loss.to_bits());
        assert_eq!(fa.fit.iters, fb.fit.iters);
        assert_eq!(fa.fit.evals, fb.fit.evals);
        assert_eq!(
            fa.fit.grad_norm.to_bits(),
            fb.fit.grad_norm.to_bits()
        );
    }
}

/// The property, for one (engine, backend) cell: fitting, persisting,
/// reloading and re-scoring must agree bit-for-bit with the purely
/// in-memory path.
fn roundtrip_case(tag: &str, method: Method, shards: usize, sgd: bool) {
    let (ds, y, dc) = cohort();
    let reduce = ReduceConfig {
        method,
        k: 0,
        ratio: 10,
        seed: 2,
        shards,
    };
    let est = EstimatorConfig {
        cv_folds: 4,
        max_iter: 120,
        ..Default::default()
    };
    let opts = FitOptions {
        sgd_epochs: if sgd { 6 } else { 0 },
        sgd_chunk: 8,
        note: format!("prop test {tag}"),
    };
    let fitted =
        fit_model(&ds, &y, &reduce, &est, &dc, &opts).unwrap();

    // in-memory predict (no disk involved) — the reference
    let inmem = fitted.predict_fold_accuracies(&ds, &y).unwrap();
    let stored: Vec<f64> =
        fitted.folds.iter().map(|f| f.accuracy).collect();
    assert_eq!(
        inmem, stored,
        "{tag}: apply-only re-score != fit-time accuracies"
    );

    // save → load → predict
    let path = scratch(tag);
    save_model(&path, &fitted).unwrap();
    let loaded = load_model(&path).unwrap();
    assert_bit_identical(&fitted, &loaded);
    // the zero-copy loader (ADR-008) agrees with the streaming one
    // bit-for-bit, on both the decoded model and the apply path
    let mapped = open_model(&path).unwrap();
    let xs = ds.data().transpose();
    assert_eq!(
        mapped.predict_proba(&xs).unwrap(),
        loaded.predict_proba(&xs).unwrap(),
        "{tag}: mapped predict != streaming predict"
    );
    assert_bit_identical(&fitted, &mapped.to_fitted().unwrap());
    let replayed = loaded.predict_fold_accuracies(&ds, &y).unwrap();
    assert_eq!(
        replayed, inmem,
        "{tag}: loaded-model predict != in-memory predict"
    );

    // the header survives a header-only parse too
    let h = read_fcm_header(&path).unwrap();
    assert_eq!(h, loaded.header);

    // saving the loaded model reproduces the file byte-for-byte
    let path2 = scratch(&format!("{tag}_resave"));
    save_model(&path2, &loaded).unwrap();
    assert_eq!(
        std::fs::read(&path).unwrap(),
        std::fs::read(&path2).unwrap(),
        "{tag}: resave is not canonical"
    );
}

#[test]
fn fastcluster_batch_roundtrips_bit_identically() {
    roundtrip_case("fast_batch", Method::Fast, 0, false);
}

#[test]
fn fastcluster_sgd_roundtrips_bit_identically() {
    roundtrip_case("fast_sgd", Method::Fast, 0, true);
}

#[test]
fn ward_batch_roundtrips_bit_identically() {
    roundtrip_case("ward_batch", Method::Ward, 0, false);
}

#[test]
fn ward_sgd_roundtrips_bit_identically() {
    roundtrip_case("ward_sgd", Method::Ward, 0, true);
}

#[test]
fn sharded_batch_roundtrips_bit_identically() {
    // shards pinned: auto shard count varies across machines
    roundtrip_case("sharded_batch", Method::FastSharded, 2, false);
}

#[test]
fn sharded_sgd_roundtrips_bit_identically() {
    roundtrip_case("sharded_sgd", Method::FastSharded, 2, true);
}

#[test]
fn batch_artifact_matches_reference_pipeline_exactly() {
    // the acceptance criterion: `repro fit --save` + `repro predict
    // --model` reproduce the in-memory `decode` fold accuracies
    let (ds, y, dc) = cohort();
    for (tag, method, shards) in [
        ("ref_fast", Method::Fast, 0),
        ("ref_ward", Method::Ward, 0),
        ("ref_sharded", Method::FastSharded, 2),
    ] {
        let reduce = ReduceConfig {
            method,
            k: 0,
            ratio: 10,
            seed: 2,
            shards,
        };
        let est = EstimatorConfig {
            cv_folds: 4,
            max_iter: 120,
            ..Default::default()
        };
        let rep =
            run_decoding_pipeline(&ds, &y, &reduce, &est).unwrap();
        let model = fit_model(
            &ds,
            &y,
            &reduce,
            &est,
            &dc,
            &FitOptions::default(),
        )
        .unwrap();
        let path = scratch(tag);
        save_model(&path, &model).unwrap();
        let loaded = load_model(&path).unwrap();
        let accs = loaded.predict_fold_accuracies(&ds, &y).unwrap();
        assert_eq!(
            accs, rep.fold_accuracies,
            "{tag}: artifact predict != decode pipeline"
        );
    }
}

#[test]
fn random_projection_model_roundtrips() {
    // RP has no labels to persist — the operator is seed-addressed
    let (ds, y, dc) = cohort();
    let reduce = ReduceConfig {
        method: Method::RandomProjection,
        k: 48,
        ratio: 0,
        seed: 9,
        shards: 0,
    };
    let est = EstimatorConfig {
        cv_folds: 3,
        max_iter: 80,
        ..Default::default()
    };
    let model = fit_model(
        &ds,
        &y,
        &reduce,
        &est,
        &dc,
        &FitOptions::default(),
    )
    .unwrap();
    let path = scratch("rp");
    save_model(&path, &model).unwrap();
    let loaded = load_model(&path).unwrap();
    assert_bit_identical(&model, &loaded);
    let accs = loaded.predict_fold_accuracies(&ds, &y).unwrap();
    let stored: Vec<f64> =
        model.folds.iter().map(|f| f.accuracy).collect();
    assert_eq!(accs, stored);
}
