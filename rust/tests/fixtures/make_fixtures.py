#!/usr/bin/env python3
"""Regenerate the committed golden fixtures.

The fixtures pin the on-disk formats against drift:

* ``tiny.json`` + ``tiny.f32raw`` — a minimal ``.fcd`` dataset,
  byte-identical to what ``volume::save_dataset`` writes (compact JSON
  with BTreeMap-sorted keys; little-endian f32 payload, row-major).
* ``tiny.fcm`` — a minimal ``.fcm`` fitted-model artifact following
  the ADR-004 layout (magic, checksummed HEAD/MASK/REDU/FOLD/"END "
  sections, CRC-32/IEEE == ``zlib.crc32``).

``rust/tests/golden_fixtures.rs`` asserts header-only parse, full
load, and that re-saving reproduces these bytes exactly. Run this
script only when the format version changes — and bump the magic /
format tag when it does.
"""

import json
import struct
import zlib
from pathlib import Path

HERE = Path(__file__).resolve().parent

# ----------------------------------------------------------- .fcd

DIMS = [3, 2, 2]
VOXELS = [0, 1, 3, 5, 6, 8, 11]  # p = 7 of 12 grid voxels
P, N = len(VOXELS), 3


def fcd() -> None:
    # compact JSON, keys sorted (rust Value::Obj is a BTreeMap),
    # integers printed without a fractional part
    header = {
        "dims": DIMS,
        "format": "fcd-v1",
        "n": N,
        "p": P,
        "voxels": VOXELS,
    }
    text = json.dumps(header, sort_keys=True, separators=(",", ":"))
    (HERE / "tiny.json").write_text(text)
    # row-major (p, n) payload; values exactly representable in f32
    values = [(i - 10) * 0.25 for i in range(P * N)]
    (HERE / "tiny.f32raw").write_bytes(
        b"".join(struct.pack("<f", v) for v in values)
    )


# ----------------------------------------------------------- .fcm

MAGIC = b"FCMODEL1"


def s(text: str) -> bytes:
    raw = text.encode()
    return struct.pack("<I", len(raw)) + raw


def section(tag: bytes, payload: bytes) -> bytes:
    assert len(tag) == 4
    return (
        tag
        + struct.pack("<Q", len(payload))
        + payload
        + struct.pack("<I", zlib.crc32(payload) & 0xFFFFFFFF)
    )


def fcm() -> None:
    head = (
        s("fast")
        + struct.pack("<III", 2, P, 6)  # k, p, n
        + struct.pack("<Q", 1)  # reduce_seed
        + struct.pack("<I", 0)  # shards
        + struct.pack("<dd", 0.001, 1e-05)  # lambda, tol
        + struct.pack("<II", 100, 2)  # max_iter, cv_folds
        + struct.pack("<II", 0, 32)  # sgd_epochs, sgd_chunk
        + struct.pack("<III", *DIMS)  # data_dims
        + struct.pack("<I", 6)  # data_n_samples
        + struct.pack("<dd", 6.0, 1.0)  # data_fwhm, data_noise_sigma
        + struct.pack("<Q", 42)  # data_seed
        + s("golden fixture")
    )
    mask = (
        struct.pack("<III", *DIMS)
        + struct.pack("<I", P)
        + struct.pack(f"<{P}I", *VOXELS)
    )
    labels = [0, 0, 1, 1, 0, 1, 1]
    redu = (
        struct.pack("<B", 0)  # kind: cluster labels
        + struct.pack("<II", 2, P)
        + struct.pack(f"<{P}I", *labels)
    )

    def fold(acc, loss, gnorm, iters, evals, b, w, test):
        return (
            struct.pack("<ddd", acc, loss, gnorm)
            + struct.pack("<QQ", iters, evals)
            + struct.pack("<f", b)
            + struct.pack("<I", len(w))
            + struct.pack(f"<{len(w)}f", *w)
            + struct.pack("<I", len(test))
            + struct.pack(f"<{len(test)}I", *test)
        )

    folds = (
        struct.pack("<I", 2)
        + fold(0.75, 0.5, 0.001, 10, 12, 0.125, [0.5, -0.25], [0, 2, 4])
        + fold(1.0, 0.25, 0.0005, 8, 9, -0.5, [1.0, 0.75], [1, 3, 5])
    )
    blob = (
        MAGIC
        + section(b"HEAD", head)
        + section(b"MASK", mask)
        + section(b"REDU", redu)
        + section(b"FOLD", folds)
        + section(b"END ", b"")
    )
    (HERE / "tiny.fcm").write_bytes(blob)


if __name__ == "__main__":
    fcd()
    fcm()
    for name in ("tiny.json", "tiny.f32raw", "tiny.fcm"):
        path = HERE / name
        print(f"{name}: {path.stat().st_size} bytes")
