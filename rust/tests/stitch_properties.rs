//! Property tests for the ADR-009 decomposition of the sharded
//! clustering engine: the distributed coordinator re-assembles a
//! parcellation from per-shard label partials computed *anywhere*, so
//! [`fit_shard`] must be a pure function of shard-local inputs and
//! [`stitch_shards`] must be invariant to how shards were assigned to
//! workers and in what order their partials arrived — and the whole
//! assembly must be bit-identical to the single-process
//! [`ShardedFastCluster::fit_trace`].
//!
//! Hand-rolled sweep harness (the offline build carries no proptest):
//! every property runs over many seeded random instances and failures
//! print the seed for exact replay.

use fastclust::cluster::{
    fit_shard, stitch_shards, Labels, ShardPlan, ShardedFastCluster,
};
use fastclust::graph::LatticeGraph;
use fastclust::rng::Rng;
use fastclust::volume::{MaskedDataset, MorphometryGenerator};

/// Sweep driver: run `prop(seed)` for `n` seeds.
fn for_seeds(n: u64, mut prop: impl FnMut(u64)) {
    for seed in 0..n {
        prop(seed);
    }
}

struct Instance {
    ds: MaskedDataset,
    graph: LatticeGraph,
    sc: ShardedFastCluster,
    k: usize,
    seed: u64,
}

/// Random small cohort + a pinned-shard engine. `k` is kept well above
/// the shard count so `resolve_shards` never collapses the plan to the
/// single-shard short-circuit.
fn instance(seed: u64) -> Instance {
    let mut rng = Rng::new(seed ^ 0x511C);
    let dims = [
        5 + rng.below(3),
        6 + rng.below(3),
        4 + rng.below(3),
    ];
    let n = 8 + rng.below(8);
    let (ds, _labels) =
        MorphometryGenerator::new(dims).generate(n, seed ^ 0xD5);
    let graph = LatticeGraph::from_mask(ds.mask());
    let k = (ds.p() / 8).max(4);
    let sc = ShardedFastCluster {
        n_shards: 2 + rng.below(3),
        ..Default::default()
    };
    Instance { ds, graph, sc, k, seed }
}

/// The coordinator's assembly: run the shard jobs in `order` (any
/// permutation — the arrival/assignment schedule), slot each partial
/// by shard id, stitch.
fn assemble(inst: &Instance, plan: &ShardPlan, order: &[usize]) -> Labels {
    let x = inst.ds.data();
    let mut slots: Vec<Option<Labels>> = vec![None; plan.n_shards];
    for &s in order {
        let rows: Vec<usize> =
            plan.members[s].iter().map(|&v| v as usize).collect();
        let xs = x.select_rows(&rows);
        let (ls, _trace) = fit_shard(
            &inst.sc.base,
            &xs,
            &plan.local_edges[s],
            plan.k_targets[s],
            plan.seeds[s],
        )
        .unwrap();
        slots[s] = Some(ls);
    }
    let shard_labels: Vec<Labels> =
        slots.into_iter().map(Option::unwrap).collect();
    let (labels, _k_total) = stitch_shards(
        x,
        &inst.graph.edges,
        inst.k,
        &plan.members,
        &shard_labels,
    )
    .unwrap();
    labels
}

/// Partials computed and stitched shard-by-shard equal the
/// single-process sharded fit bitwise — the ADR-009 identity contract.
#[test]
fn prop_assembled_stitch_matches_single_process_fit() {
    for_seeds(8, |seed| {
        let inst = instance(seed);
        let plan =
            inst.sc.plan(&inst.graph, inst.k, inst.seed).unwrap();
        let order: Vec<usize> = (0..plan.n_shards).collect();
        let assembled = assemble(&inst, &plan, &order);
        let (reference, _trace) = inst
            .sc
            .fit_trace(inst.ds.data(), &inst.graph, inst.k, inst.seed)
            .unwrap();
        assert_eq!(assembled.k, reference.k, "seed {seed}");
        assert_eq!(
            assembled.labels, reference.labels,
            "seed {seed}: assembled stitch != single-process fit"
        );
    });
}

/// Any arrival order / shard-to-worker schedule stitches identically:
/// shuffled execution orders all reproduce the natural-order bits.
#[test]
fn prop_stitch_is_arrival_order_invariant() {
    for_seeds(6, |seed| {
        let inst = instance(seed);
        let plan =
            inst.sc.plan(&inst.graph, inst.k, inst.seed).unwrap();
        let natural: Vec<usize> = (0..plan.n_shards).collect();
        let want = assemble(&inst, &plan, &natural);
        let mut rng = Rng::new(seed ^ 0x0DE2);
        for _ in 0..3 {
            let mut order = natural.clone();
            rng.shuffle(&mut order);
            let got = assemble(&inst, &plan, &order);
            assert_eq!(
                got.labels, want.labels,
                "seed {seed}: stitch depends on arrival order {order:?}"
            );
        }
    });
}

/// `fit_shard` is pure: a retried or re-assigned shard job (the
/// coordinator's recovery path) returns bit-equal labels.
#[test]
fn prop_fit_shard_rerun_is_bit_identical() {
    for_seeds(6, |seed| {
        let inst = instance(seed);
        let plan =
            inst.sc.plan(&inst.graph, inst.k, inst.seed).unwrap();
        let x = inst.ds.data();
        for s in 0..plan.n_shards {
            let rows: Vec<usize> =
                plan.members[s].iter().map(|&v| v as usize).collect();
            let xs = x.select_rows(&rows);
            let run = || {
                fit_shard(
                    &inst.sc.base,
                    &xs,
                    &plan.local_edges[s],
                    plan.k_targets[s],
                    plan.seeds[s],
                )
                .unwrap()
                .0
            };
            let a = run();
            let b = run();
            assert_eq!(
                a.labels, b.labels,
                "seed {seed} shard {s}: fit_shard drifted on rerun"
            );
            assert_eq!(a.k, b.k, "seed {seed} shard {s}");
        }
    });
}
