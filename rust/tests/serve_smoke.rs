//! Concurrency smoke test for the decode server (ADR-004): ≥8 client
//! threads hammer one loopback `serve` instance concurrently; every
//! response must be bit-identical to the offline apply-only path on
//! the same artifact, and shutdown must drain every thread the
//! server spawned (accept loop, connection readers, WorkerPool).
//!
//! The server writes its event log to `$CARGO_TARGET_TMPDIR/
//! serve_smoke.log`; CI uploads that file when this suite fails.

use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;

use fastclust::config::{
    DataConfig, EstimatorConfig, Method, ReduceConfig,
};
use fastclust::model::{
    fit_model, load_model, save_model, FitOptions, FittedModel,
};
use fastclust::serve::{
    Request, Response, ServeClient, ServeOptions, Server,
};
use fastclust::volume::{FeatureMatrix, MorphometryGenerator};

const N_CLIENTS: usize = 8;
const SAMPLES_PER_CLIENT: usize = 3;

fn tmp(name: &str) -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_TARGET_TMPDIR"));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Fit + persist a model and return (path, loaded model, cohort
/// sample-major features) — the offline truth the served responses
/// must reproduce bit-for-bit.
fn fixture(
    tag: &str,
) -> (PathBuf, Arc<FittedModel>, Arc<FeatureMatrix>) {
    let dc = DataConfig {
        dims: [10, 11, 9],
        n_samples: 40,
        seed: 23,
        ..Default::default()
    };
    let (ds, y) =
        MorphometryGenerator::new(dc.dims).generate(dc.n_samples, dc.seed);
    let reduce = ReduceConfig {
        method: Method::Fast,
        ratio: 10,
        ..Default::default()
    };
    let est = EstimatorConfig {
        cv_folds: 3,
        max_iter: 80,
        ..Default::default()
    };
    let model = fit_model(
        &ds,
        &y,
        &reduce,
        &est,
        &dc,
        &FitOptions::default(),
    )
    .unwrap();
    let path = tmp(&format!("serve_smoke_{tag}.fcm"));
    save_model(&path, &model).unwrap();
    // serve and verify against the artifact actually on disk
    let loaded = Arc::new(load_model(&path).unwrap());
    let xs = Arc::new(ds.data().transpose()); // (n, p) sample-major
    (path, loaded, xs)
}

/// The `(SAMPLES_PER_CLIENT, p)` block client `c` sends: a strided
/// slice of the cohort, distinct per client.
fn client_block(xs: &FeatureMatrix, c: usize) -> FeatureMatrix {
    let rows: Vec<usize> = (0..SAMPLES_PER_CLIENT)
        .map(|i| (c + i * N_CLIENTS) % xs.rows)
        .collect();
    xs.select_rows(&rows)
}

#[test]
fn eight_concurrent_clients_get_bit_identical_answers() {
    let (path, model, xs) = fixture("main");
    let log_path = tmp("serve_smoke.log");
    let mut opts = ServeOptions::new(&path);
    opts.workers = 4;
    opts.log_path = Some(log_path.clone());
    let handle = Server::start(opts).unwrap();
    let addr = handle.addr();

    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for c in 0..N_CLIENTS {
            let model = model.clone();
            let xs = xs.clone();
            joins.push(scope.spawn(move || {
                let block = client_block(&xs, c);
                // offline truth, computed independently per thread
                let want_p = model.predict_proba(&block).unwrap();
                let want_x = model.compress(&block).unwrap();
                let mut client = ServeClient::connect(addr).unwrap();
                let info = client.model_info().unwrap();
                assert_eq!(
                    info.get("k").unwrap().as_usize().unwrap(),
                    model.header.k,
                    "client {c}: wrong model served"
                );
                // several sequential rounds to overlap with the
                // other clients' traffic
                for round in 0..3 {
                    let got = client.predict(&block).unwrap();
                    assert_eq!(
                        got, want_p,
                        "client {c} round {round}: served predict \
                         != offline decode"
                    );
                    let xk = client.compress(&block).unwrap();
                    assert_eq!(
                        xk.data, want_x.data,
                        "client {c} round {round}: served compress \
                         != offline reduce"
                    );
                }
                // pipelined batch: requests written back-to-back so
                // the server's per-connection batching kicks in
                let rqs: Vec<Request> = (0..4)
                    .map(|_| Request::Predict {
                        model: String::new(),
                        x: block.clone(),
                    })
                    .collect();
                let responses = client.call_pipelined(&rqs).unwrap();
                assert_eq!(responses.len(), 4);
                for rs in responses {
                    match rs {
                        Response::Probabilities(p) => {
                            assert_eq!(p, want_p, "client {c}: \
                                 pipelined predict drifted")
                        }
                        other => {
                            panic!("client {c}: {other:?}")
                        }
                    }
                }
            }));
        }
        for j in joins {
            j.join().expect("client thread panicked");
        }
    });

    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.connections, N_CLIENTS as u64);
    // per client: 1 info + 3×(predict+compress) + 4 pipelined = 11
    assert_eq!(stats.requests, (N_CLIENTS * 11) as u64);
    assert_eq!(stats.errors, 0, "no request may have errored");
    assert!(stats.batches <= stats.requests);

    // shutdown is real: the listener is gone...
    assert!(
        TcpStream::connect(addr).is_err(),
        "server still accepting after shutdown"
    );
    // ...and the log recorded an orderly lifecycle
    let log = std::fs::read_to_string(&log_path).unwrap();
    assert!(log.contains("listening on"), "log:\n{log}");
    assert!(log.contains("worker pool drained"), "log:\n{log}");
    assert!(log.contains("accept loop exited"), "log:\n{log}");
}

#[test]
fn shutdown_with_no_traffic_is_clean() {
    let (path, _, _) = fixture("idle");
    let mut opts = ServeOptions::new(&path);
    opts.workers = 2;
    opts.log_path = Some(tmp("serve_smoke_idle.log"));
    let handle = Server::start(opts).unwrap();
    let addr = handle.addr();
    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.requests, 0);
    assert!(TcpStream::connect(addr).is_err());
}

#[test]
fn client_disconnect_mid_session_does_not_wedge_the_server() {
    let (path, model, xs) = fixture("disc");
    let mut opts = ServeOptions::new(&path);
    opts.workers = 2;
    opts.log_path = Some(tmp("serve_smoke_disc.log"));
    let handle = Server::start(opts).unwrap();
    let addr = handle.addr();
    // a client that connects and hangs up without a single frame
    drop(TcpStream::connect(addr).unwrap());
    // a normal client still gets served afterwards
    let block = client_block(&xs, 0);
    let want = model.predict_proba(&block).unwrap();
    let mut client = ServeClient::connect(addr).unwrap();
    assert_eq!(client.predict(&block).unwrap(), want);
    drop(client);
    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.connections, 2);
    assert_eq!(stats.errors, 0);
}
