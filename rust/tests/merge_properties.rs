//! Property tests for the algebra the distributed fit (ADR-006)
//! rests on: [`ReduceAccumulator::merge`] must behave like a
//! commutative, associative union of disjoint column ranges — so any
//! partition of the sample axis, reduced anywhere and merged in any
//! order, reproduces the in-memory reduction bit-for-bit — and the
//! SGD fold fit must be a pure function of its inputs, so a retried
//! or re-assigned fold job returns the same `LogregFit` bits.
//!
//! Hand-rolled sweep harness (the offline build carries no proptest):
//! every property runs over many seeded random instances and failures
//! print the seed for exact replay.

use fastclust::cluster::Labels;
use fastclust::config::EstimatorConfig;
use fastclust::estimators::{SgdLogisticRegression, SgdState};
use fastclust::model::fit_one_fold;
use fastclust::reduce::{
    ClusterReduce, ReduceAccumulator, Reducer, SparseRandomProjection,
    StreamingReducer,
};
use fastclust::rng::Rng;
use fastclust::volume::FeatureMatrix;

/// Sweep driver: run `prop(seed)` for `n` seeds.
fn for_seeds(n: u64, mut prop: impl FnMut(u64)) {
    for seed in 0..n {
        prop(seed);
    }
}

fn cohort(p: usize, n: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed ^ 0xC0C0);
    let mut x = FeatureMatrix::zeros(p, n);
    rng.fill_normal(&mut x.data);
    x
}

/// Random contiguous partition of `0..n` into 1..=max_parts ranges.
fn random_partition(
    n: usize,
    max_parts: usize,
    rng: &mut Rng,
) -> Vec<(usize, usize)> {
    let parts = 1 + rng.below(max_parts.min(n));
    let mut cuts: Vec<usize> =
        (0..parts - 1).map(|_| 1 + rng.below(n - 1)).collect();
    cuts.push(0);
    cuts.push(n);
    cuts.sort_unstable();
    cuts.dedup();
    cuts.windows(2).map(|w| (w[0], w[1] - w[0])).collect()
}

/// Reduce one `(col0, count)` range into its own accumulator.
fn shard_acc(
    red: &dyn Reducer,
    x: &FeatureMatrix,
    col0: usize,
    count: usize,
) -> ReduceAccumulator {
    let cols: Vec<usize> = (col0..col0 + count).collect();
    let mut acc = red.begin(x.cols);
    red.reduce_chunk(&mut acc, col0, &x.select_cols(&cols)).unwrap();
    acc
}

fn reducers(p: usize, seed: u64) -> Vec<Box<dyn Reducer>> {
    let k = 3 + (seed as usize % 4);
    let labels = Labels::new(
        (0..p as u32).map(|i| i % k as u32).collect(),
        k,
    )
    .unwrap();
    vec![
        Box::new(ClusterReduce::from_labels(&labels)),
        Box::new(SparseRandomProjection::new(p, k, seed ^ 0x5EED)),
    ]
}

/// Any random disjoint partition, merged in any (shuffled) order,
/// equals the full in-memory reduction bitwise.
#[test]
fn prop_merge_of_random_partition_is_bit_identical() {
    for_seeds(10, |seed| {
        let mut rng = Rng::new(seed);
        let p = 12 + rng.below(30);
        let n = 6 + rng.below(20);
        let x = cohort(p, n, seed);
        for red in reducers(p, seed) {
            let full = red.reduce(&x);
            let ranges = random_partition(n, 6, &mut rng);
            let mut shards: Vec<ReduceAccumulator> = ranges
                .iter()
                .map(|&(c0, cnt)| shard_acc(red.as_ref(), &x, c0, cnt))
                .collect();
            rng.shuffle(&mut shards);
            let mut acc = red.begin(n);
            for s in &shards {
                acc.merge(s).unwrap();
            }
            assert_eq!(acc.cols_filled(), n, "seed {seed}");
            assert_eq!(
                acc.finish().unwrap().data,
                full.data,
                "seed {seed} k={}: merged partition != full reduce",
                red.k()
            );
        }
    });
}

/// merge is commutative: a⊕b and b⊕a yield identical matrices.
#[test]
fn prop_merge_commutes() {
    for_seeds(8, |seed| {
        let mut rng = Rng::new(seed ^ 0xAB);
        let p = 10 + rng.below(20);
        let n = 4 + rng.below(12);
        let split = 1 + rng.below(n - 1);
        let x = cohort(p, n, seed);
        for red in reducers(p, seed) {
            let a = shard_acc(red.as_ref(), &x, 0, split);
            let b = shard_acc(red.as_ref(), &x, split, n - split);
            let mut ab = a.clone();
            ab.merge(&b).unwrap();
            let mut ba = b.clone();
            ba.merge(&a).unwrap();
            assert_eq!(
                ab.finish().unwrap().data,
                ba.finish().unwrap().data,
                "seed {seed} k={}: merge not commutative",
                red.k()
            );
        }
    });
}

/// merge is associative: (a⊕b)⊕c == a⊕(b⊕c), so linear fold-in and
/// tree merges (as a multi-level coordinator would do) agree.
#[test]
fn prop_merge_associates() {
    for_seeds(8, |seed| {
        let mut rng = Rng::new(seed ^ 0xCD);
        let p = 10 + rng.below(20);
        let n = 6 + rng.below(12);
        let c1 = 1 + rng.below(n - 2);
        let c2 = c1 + 1 + rng.below(n - c1 - 1);
        let x = cohort(p, n, seed);
        for red in reducers(p, seed) {
            let a = shard_acc(red.as_ref(), &x, 0, c1);
            let b = shard_acc(red.as_ref(), &x, c1, c2 - c1);
            let c = shard_acc(red.as_ref(), &x, c2, n - c2);
            let mut left = a.clone();
            left.merge(&b).unwrap();
            left.merge(&c).unwrap();
            let mut right_inner = b.clone();
            right_inner.merge(&c).unwrap();
            let mut right = a.clone();
            right.merge(&right_inner).unwrap();
            assert_eq!(
                left.finish().unwrap().data,
                right.finish().unwrap().data,
                "seed {seed} k={}: merge not associative",
                red.k()
            );
        }
    });
}

/// Overlapping shards are rejected, never silently summed — the
/// exactly-once guarantee a retrying coordinator depends on.
#[test]
fn prop_overlapping_merge_always_rejected() {
    for_seeds(10, |seed| {
        let mut rng = Rng::new(seed ^ 0xEF);
        let p = 10 + rng.below(16);
        let n = 5 + rng.below(10);
        let x = cohort(p, n, seed);
        let rs = reducers(p, seed);
        let red = &rs[0];
        // two ranges sharing at least the pivot column
        let pivot = rng.below(n);
        let a = shard_acc(red.as_ref(), &x, 0, pivot + 1);
        let b = shard_acc(red.as_ref(), &x, pivot, n - pivot);
        let mut acc = a.clone();
        assert!(
            acc.merge(&b).is_err(),
            "seed {seed}: overlap at column {pivot} accepted"
        );
        // a duplicated shard (the retry-then-original-arrives race)
        // is likewise rejected
        let mut dup = a.clone();
        assert!(dup.merge(&a).is_err(), "seed {seed}: self-merge ok'd");
    });
}

fn toy_fold(
    seed: u64,
) -> (FeatureMatrix, Vec<f32>, FeatureMatrix, Vec<f32>) {
    let mut rng = Rng::new(seed ^ 0xF01D);
    let k = 3 + rng.below(5);
    let ntr = 12 + rng.below(20);
    let nte = 4 + rng.below(8);
    let mk = |n: usize, rng: &mut Rng| {
        let mut x = FeatureMatrix::zeros(n, k);
        let mut y = vec![0.0f32; n];
        for i in 0..n {
            let cls = (i % 2) as f32;
            for j in 0..k {
                x.set(i, j, rng.normal32() + (cls - 0.5) * 2.0);
            }
            y[i] = cls;
        }
        (x, y)
    };
    let (xtr, ytr) = mk(ntr, &mut rng);
    let (xte, yte) = mk(nte, &mut rng);
    (xtr, ytr, xte, yte)
}

/// partial_fit is deterministic: replaying the same chunk sequence —
/// straight through, or snapshot-cloned mid-stream and resumed —
/// produces bit-equal weights, intercept and step count.
#[test]
fn prop_sgd_replay_is_bit_deterministic() {
    for_seeds(8, |seed| {
        let (xtr, ytr, _, _) = toy_fold(seed);
        let sgd = SgdLogisticRegression::default();
        let mut rng = Rng::new(seed ^ 0x51D);
        let chunk = 1 + rng.below(6);
        let run = |epochs: usize| -> SgdState {
            let mut st = sgd.init(xtr.cols);
            for _ in 0..epochs {
                let mut r0 = 0;
                while r0 < xtr.rows {
                    let r1 = (r0 + chunk).min(xtr.rows);
                    let xc = xtr.row_block(r0, r1);
                    sgd.partial_fit(&mut st, &xc, &ytr[r0..r1]).unwrap();
                    r0 = r1;
                }
            }
            st
        };
        let a = run(2);
        let b = run(2);
        assert_eq!(a.w, b.w, "seed {seed}: replay drifted");
        assert_eq!(a.b.to_bits(), b.b.to_bits(), "seed {seed}");
        assert_eq!(a.steps, b.steps, "seed {seed}");
        // snapshot/resume: clone after epoch 1, run epoch 2 on both
        let mid = run(1);
        let mut resumed = mid.clone();
        let mut r0 = 0;
        while r0 < xtr.rows {
            let r1 = (r0 + chunk).min(xtr.rows);
            let xc = xtr.row_block(r0, r1);
            sgd.partial_fit(&mut resumed, &xc, &ytr[r0..r1]).unwrap();
            r0 = r1;
        }
        assert_eq!(
            resumed.w, a.w,
            "seed {seed}: snapshot+resume != straight-through"
        );
        assert_eq!(resumed.b.to_bits(), a.b.to_bits(), "seed {seed}");
    });
}

/// `fit_one_fold` is a pure function: re-running it (the coordinator's
/// retry path and its local fallback both do exactly this) returns
/// bit-equal weights and accuracy, for both the batch and SGD paths.
#[test]
fn prop_fold_fit_rerun_is_bit_identical() {
    for_seeds(6, |seed| {
        let (xtr, ytr, xte, yte) = toy_fold(seed);
        let est = EstimatorConfig {
            cv_folds: 2,
            max_iter: 60,
            ..Default::default()
        };
        for (epochs, chunk) in [(0usize, 0usize), (2, 5)] {
            let (f1, a1) = fit_one_fold(
                &xtr, &ytr, &xte, &yte, &est, epochs, chunk,
            )
            .unwrap();
            let (f2, a2) = fit_one_fold(
                &xtr, &ytr, &xte, &yte, &est, epochs, chunk,
            )
            .unwrap();
            let bits = |w: &[f32]| -> Vec<u32> {
                w.iter().map(|v| v.to_bits()).collect()
            };
            assert_eq!(
                bits(&f1.w),
                bits(&f2.w),
                "seed {seed} epochs {epochs}: weights drifted on rerun"
            );
            assert_eq!(f1.b.to_bits(), f2.b.to_bits(), "seed {seed}");
            assert_eq!(f1.iters, f2.iters, "seed {seed}");
            assert_eq!(a1.to_bits(), a2.to_bits(), "seed {seed}");
        }
    });
}
