//! Fault-injection suite for the distributed fit (ADR-006): every
//! scenario — clean fleet, killed worker, dropped / corrupted /
//! delayed PARTIAL — must converge to a `.fcm` byte-identical to the
//! single-process [`fit_model`] artifact, with the recovery visible
//! in the coordinator event log. Workers are real spawned processes
//! of the `repro` binary (`CARGO_BIN_EXE_repro`), so the wire
//! protocol, heartbeats and process death are exercised for real.

use std::path::PathBuf;
use std::process::Command;

use fastclust::config::{
    DataConfig, DistSettings, EstimatorConfig, ExperimentConfig, Method,
    ReduceConfig,
};
use fastclust::coordinator::{
    run_distributed_fit, DistOptions, DistReport, FaultKind, FaultSpec,
};
use fastclust::model::{fit_model, save_model, FitOptions};
use fastclust::volume::{MaskedDataset, MorphometryGenerator};

fn tmp(name: &str) -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_TARGET_TMPDIR"));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

struct Fixture {
    ds: MaskedDataset,
    labels: Vec<u8>,
    reduce: ReduceConfig,
    est: EstimatorConfig,
    dc: DataConfig,
    opts: FitOptions,
    local_bytes: Vec<u8>,
}

/// Small cohort + the single-process reference artifact bytes.
fn fixture(tag: &str) -> Fixture {
    let dc = DataConfig {
        dims: [8, 9, 7],
        n_samples: 18,
        seed: 33,
        ..Default::default()
    };
    let (ds, labels) =
        MorphometryGenerator::new(dc.dims).generate(dc.n_samples, dc.seed);
    let reduce = ReduceConfig {
        method: Method::Fast,
        ratio: 10,
        ..Default::default()
    };
    let est = EstimatorConfig {
        cv_folds: 3,
        max_iter: 60,
        ..Default::default()
    };
    let opts = FitOptions::default();
    let model =
        fit_model(&ds, &labels, &reduce, &est, &dc, &opts).unwrap();
    let path = tmp(&format!("dist_faults_{tag}_local.fcm"));
    save_model(&path, &model).unwrap();
    let local_bytes = std::fs::read(&path).unwrap();
    Fixture { ds, labels, reduce, est, dc, opts, local_bytes }
}

/// DistOptions for a test: real worker binary, per-test work dir
/// (the pid-keyed default would collide across parallel tests),
/// small chunks so every reduce job spans several PARTIAL frames
/// (the injection ordinals must exist). The bind address is left at
/// the `127.0.0.1:0` default on purpose: the coordinator discovers
/// the kernel-assigned ephemeral port via `local_addr()` and hands
/// it to the spawned workers, so parallel tests (and parallel CI
/// jobs) can never collide on a fixed port.
fn dist_opts(tag: &str, workers: usize) -> DistOptions {
    let work = tmp(&format!("dist_faults_{tag}_work"));
    std::fs::create_dir_all(&work).unwrap();
    DistOptions {
        workers,
        chunk_samples: 4,
        worker_bin: Some(PathBuf::from(env!("CARGO_BIN_EXE_repro"))),
        work_dir: Some(work),
        ..Default::default()
    }
}

/// Run distributed, save, byte-compare against the local reference.
fn run_and_compare(
    fx: &Fixture,
    dist: &DistOptions,
    tag: &str,
) -> DistReport {
    let (model, report) = run_distributed_fit(
        &fx.ds, &fx.labels, &fx.reduce, &fx.est, &fx.dc, &fx.opts, dist,
    )
    .unwrap_or_else(|e| panic!("{tag}: distributed fit failed: {e}"));
    let path = tmp(&format!("dist_faults_{tag}.fcm"));
    save_model(&path, &model).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    assert_eq!(
        bytes, fx.local_bytes,
        "{tag}: distributed .fcm differs from single-process artifact \
         (events: {:?})",
        report.events
    );
    if let Some(w) = &dist.work_dir {
        let _ = std::fs::remove_dir_all(w);
    }
    report
}

fn has_event(r: &DistReport, needle: &str) -> bool {
    r.events.iter().any(|(_, m)| m.contains(needle))
}

#[test]
fn clean_three_worker_fit_is_bit_identical() {
    let fx = fixture("clean");
    let dist = dist_opts("clean", 3);
    let report = run_and_compare(&fx, &dist, "clean");
    assert_eq!(report.workers_connected, 3);
    assert_eq!(report.retries, 0, "clean run must not retry");
    assert_eq!(report.local_jobs, 0, "clean run must not fall back");
    assert_eq!(report.workers_lost, 0);
    assert!(report.reduce_jobs > 0 && report.fold_jobs > 0);
    assert_eq!(report.topology.len(), 3);
}

#[test]
fn killed_sole_worker_falls_back_locally_and_matches() {
    let fx = fixture("kill1");
    let dist = DistOptions {
        inject: Some(FaultSpec { kind: FaultKind::Kill, worker: 0 }),
        ..dist_opts("kill1", 1)
    };
    let report = run_and_compare(&fx, &dist, "kill1");
    assert!(report.workers_lost >= 1, "worker death not noticed");
    assert!(
        report.retries >= 1 || report.local_jobs >= 1,
        "no recovery recorded: {report:?}"
    );
    assert!(report.local_jobs >= 1, "no local fallback with 0 \
         surviving workers");
    assert!(has_event(&report, "local fallback"), "{:?}", report.events);
}

#[test]
fn killed_worker_among_three_is_absorbed_by_survivors() {
    let fx = fixture("kill3");
    let dist = DistOptions {
        inject: Some(FaultSpec { kind: FaultKind::Kill, worker: 0 }),
        ..dist_opts("kill3", 3)
    };
    let report = run_and_compare(&fx, &dist, "kill3");
    assert_eq!(report.workers_connected, 3);
    assert!(report.workers_lost >= 1, "worker death not noticed");
    assert!(
        has_event(&report, "requeue job")
            || has_event(&report, "local fallback"),
        "no re-assignment in the log: {:?}",
        report.events
    );
}

#[test]
fn dropped_partial_is_soft_retried_on_the_live_worker() {
    let fx = fixture("drop");
    let dist = DistOptions {
        inject: Some(FaultSpec { kind: FaultKind::Drop, worker: 0 }),
        ..dist_opts("drop", 1)
    };
    let report = run_and_compare(&fx, &dist, "drop");
    assert!(report.retries >= 1, "dropped PARTIAL not retried");
    assert_eq!(
        report.workers_lost, 0,
        "a soft failure must keep the connection"
    );
    assert!(has_event(&report, "requeue job"), "{:?}", report.events);
}

#[test]
fn corrupted_partial_is_rejected_by_checksum_and_recovered() {
    let fx = fixture("corrupt");
    let dist = DistOptions {
        inject: Some(FaultSpec { kind: FaultKind::Corrupt, worker: 0 }),
        ..dist_opts("corrupt", 1)
    };
    let report = run_and_compare(&fx, &dist, "corrupt");
    assert!(
        has_event(&report, "checksum"),
        "corruption not caught by the frame checksum: {:?}",
        report.events
    );
    assert!(report.retries >= 1 || report.local_jobs >= 1);
}

#[test]
fn delayed_worker_hits_the_heartbeat_timeout() {
    let fx = fixture("delay");
    let dist = DistOptions {
        inject: Some(FaultSpec { kind: FaultKind::Delay, worker: 0 }),
        heartbeat_ms: 600,
        ..dist_opts("delay", 1)
    };
    let report = run_and_compare(&fx, &dist, "delay");
    assert!(
        has_event(&report, "heartbeat timeout"),
        "stall not detected: {:?}",
        report.events
    );
    assert!(report.workers_lost >= 1);
    assert!(report.local_jobs >= 1);
}

/// End-to-end through the CLI: `repro fit` vs
/// `repro fit-distributed --workers 2`, clean and with an injected
/// kill — all three `.fcm` artifacts must be byte-identical, and the
/// distributed runs must leave a `.dist.json` topology sidecar.
#[test]
fn cli_fit_distributed_matches_cli_fit() {
    let repro = env!("CARGO_BIN_EXE_repro");
    let cfg = ExperimentConfig {
        data: DataConfig {
            dims: [8, 9, 7],
            n_samples: 18,
            seed: 47,
            ..Default::default()
        },
        reduce: ReduceConfig {
            method: Method::Fast,
            ratio: 10,
            ..Default::default()
        },
        estimator: EstimatorConfig {
            cv_folds: 3,
            max_iter: 60,
            ..Default::default()
        },
        dist: DistSettings { workers: 2, ..Default::default() },
        ..Default::default()
    };
    let cfg_path = tmp("dist_faults_cli.json");
    std::fs::write(&cfg_path, cfg.to_json().to_string_pretty())
        .unwrap();

    let run = |args: &[&str]| {
        let out = Command::new(repro).args(args).output().unwrap();
        assert!(
            out.status.success(),
            "repro {args:?} failed:\n{}\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
    };

    let local = tmp("dist_faults_cli_local.fcm");
    let clean = tmp("dist_faults_cli_clean.fcm");
    let fault = tmp("dist_faults_cli_fault.fcm");
    let cfg_s = cfg_path.to_str().unwrap();
    run(&["fit", "--config", cfg_s, "--save", local.to_str().unwrap()]);
    run(&[
        "fit-distributed",
        "--config",
        cfg_s,
        "--save",
        clean.to_str().unwrap(),
    ]);
    run(&[
        "fit-distributed",
        "--config",
        cfg_s,
        "--save",
        fault.to_str().unwrap(),
        "--inject",
        "kill:0",
    ]);

    let want = std::fs::read(&local).unwrap();
    assert_eq!(
        std::fs::read(&clean).unwrap(),
        want,
        "CLI distributed artifact differs from CLI fit"
    );
    assert_eq!(
        std::fs::read(&fault).unwrap(),
        want,
        "CLI distributed artifact differs after fault recovery"
    );
    for p in [&clean, &fault] {
        let sidecar =
            PathBuf::from(format!("{}.dist.json", p.display()));
        let txt = std::fs::read_to_string(&sidecar)
            .unwrap_or_else(|e| panic!("missing sidecar: {e}"));
        assert!(txt.contains("topology"), "sidecar lacks topology");
    }
}
