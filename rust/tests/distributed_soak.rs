//! Fault soak for the distributed stage-1 parcellation (ADR-009):
//! many rounds of `run_distributed_fit` with `distribute_clustering`
//! on, an 8-worker fleet and a *randomized* fault drawn from a seeded
//! RNG each round (none / kill / drop / corrupt / delay, against a
//! random worker). Every round the saved `.fcm` must be byte-identical
//! to the single-process fast-sharded [`fit_model`] artifact — the
//! fleet size, the arrival order and the injected fault are all
//! scheduling noise by contract.
//!
//! The jobs run in wire mode (`stem = ""`), so workers never see the
//! staged `.fcd` path: every voxel/sample block crosses the socket via
//! FETCH/DATA range serving, which the clean round asserts directly
//! (`range_blocks > 0`, `local_jobs == 0`).
//!
//! Each round appends its event log to
//! `$CARGO_TARGET_TMPDIR/dist_soak_events.log` before asserting, so a
//! CI failure ships the full soak history as an artifact.
//!
//! Two further soak families ride the same fixture (ADR-010):
//!
//! * **chaos rounds** — the fleet runs as *external* worker processes
//!   whose connections cross a seeded [`ChaosProxy`] (latency, frame
//!   splits, blackholes, RSTs, half-closes on the coordinator wire).
//!   Whatever the schedule does to the sockets, the `.fcm` must stay
//!   byte-identical to the single-process artifact.
//! * **kill/resume rounds** — `repro fit-distributed` runs as a child
//!   process, is SIGKILLed at a seeded point of its `.fcj` journal,
//!   and is completed with `--resume`: the resumed artifact must be
//!   byte-identical to an uninterrupted child run's.
//!
//! `soak_quick` / `chaos_quick` / `kill_resume_quick` run in the
//! distributed-smoke CI job; the longer `*_long` variants are
//! `#[ignore]`d for the nightly chaos-soak job:
//! `cargo test --test distributed_soak -- --ignored`.

use std::io::Write;
use std::net::{SocketAddr, TcpListener};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use fastclust::config::{
    DataConfig, DistSettings, EstimatorConfig, ExperimentConfig,
    Method, ReduceConfig, StreamConfig,
};
use fastclust::coordinator::{
    run_distributed_fit, DistOptions, DistReport, FaultKind, FaultSpec,
};
use fastclust::model::{fit_model, save_model, FitOptions};
use fastclust::rng::Rng;
use fastclust::testkit::{ChaosProxy, Fault};
use fastclust::volume::{MaskedDataset, MorphometryGenerator};

fn tmp(name: &str) -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_TARGET_TMPDIR"));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

struct Fixture {
    ds: MaskedDataset,
    labels: Vec<u8>,
    reduce: ReduceConfig,
    est: EstimatorConfig,
    dc: DataConfig,
    opts: FitOptions,
    local_bytes: Vec<u8>,
}

/// Small cohort, fast-sharded stage 1 with a *pinned* shard count
/// (shards = 0 would resolve from the core count and the plan must be
/// machine-independent here), plus the single-process reference bytes.
fn fixture(tag: &str) -> Fixture {
    let dc = DataConfig {
        dims: [8, 9, 7],
        n_samples: 18,
        seed: 33,
        ..Default::default()
    };
    let (ds, labels) =
        MorphometryGenerator::new(dc.dims).generate(dc.n_samples, dc.seed);
    let reduce = ReduceConfig {
        method: Method::FastSharded,
        ratio: 10,
        shards: 3,
        ..Default::default()
    };
    let est = EstimatorConfig {
        cv_folds: 3,
        max_iter: 60,
        ..Default::default()
    };
    let opts = FitOptions::default();
    let model =
        fit_model(&ds, &labels, &reduce, &est, &dc, &opts).unwrap();
    let path = tmp(&format!("dist_soak_{tag}_local.fcm"));
    save_model(&path, &model).unwrap();
    let local_bytes = std::fs::read(&path).unwrap();
    Fixture { ds, labels, reduce, est, dc, opts, local_bytes }
}

/// Draw this round's fault from the soak RNG: roughly one round in
/// five is clean, the rest spread over the four fault kinds, each
/// aimed at a uniformly random member of the fleet.
fn draw_fault(rng: &mut Rng, workers: usize) -> Option<FaultSpec> {
    let kind = match rng.below(5) {
        0 => return None,
        1 => FaultKind::Kill,
        2 => FaultKind::Drop,
        3 => FaultKind::Corrupt,
        _ => FaultKind::Delay,
    };
    Some(FaultSpec { kind, worker: rng.below(workers) })
}

fn fault_name(f: &Option<FaultSpec>) -> String {
    match f {
        None => "clean".into(),
        Some(s) => format!("{:?}:{}", s.kind, s.worker),
    }
}

/// One soak round: distributed fit with the drawn fault, event log
/// appended to the soak artifact, `.fcm` byte-compared against the
/// reference.
fn soak_round(
    fx: &Fixture,
    tag: &str,
    round: usize,
    workers: usize,
    inject: Option<FaultSpec>,
) -> DistReport {
    let work = tmp(&format!("dist_soak_{tag}_work"));
    std::fs::create_dir_all(&work).unwrap();
    let dist = DistOptions {
        workers,
        chunk_samples: 4,
        heartbeat_ms: 600,
        worker_bin: Some(PathBuf::from(env!("CARGO_BIN_EXE_repro"))),
        work_dir: Some(work.clone()),
        distribute_clustering: true,
        inject: inject.clone(),
        ..Default::default()
    };
    let label = format!("{tag} round {round} [{}]", fault_name(&inject));
    let (model, report) = run_distributed_fit(
        &fx.ds, &fx.labels, &fx.reduce, &fx.est, &fx.dc, &fx.opts, &dist,
    )
    .unwrap_or_else(|e| panic!("{label}: distributed fit failed: {e}"));

    // Event-log artifact first, assertions second: a failed round must
    // still leave its history on disk for the CI upload.
    let mut log = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(tmp("dist_soak_events.log"))
        .unwrap();
    writeln!(
        log,
        "=== {label}: cluster_jobs={} range_blocks={} retries={} \
         local_jobs={} workers_lost={}",
        report.cluster_jobs,
        report.range_blocks,
        report.retries,
        report.local_jobs,
        report.workers_lost
    )
    .unwrap();
    for e in &report.events {
        writeln!(log, "{e:?}").unwrap();
    }

    let path = tmp(&format!("dist_soak_{tag}_round{round}.fcm"));
    save_model(&path, &model).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    assert_eq!(
        bytes, fx.local_bytes,
        "{label}: distributed .fcm differs from the single-process \
         fast-sharded artifact (events: {:?})",
        report.events
    );
    assert_eq!(
        report.cluster_jobs, 3,
        "{label}: stage 1 was not sharded into the pinned shard count"
    );
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_dir_all(&work);
    report
}

fn soak(tag: &str, rounds: usize, workers: usize, seed: u64) {
    let fx = fixture(tag);
    let mut rng = Rng::new(seed);
    let mut faulted = 0usize;
    for round in 0..rounds {
        // Round 0 is forced clean so the range-serving path is
        // asserted unconditionally at least once per soak; the last
        // round is forced faulty if the RNG never injected anything
        // (a soak that only ran clean rounds proves nothing).
        let inject = if round == 0 {
            None
        } else if round + 1 == rounds && faulted == 0 {
            Some(FaultSpec { kind: FaultKind::Kill, worker: 0 })
        } else {
            draw_fault(&mut rng, workers)
        };
        let clean = inject.is_none();
        faulted += usize::from(!clean);
        let report = soak_round(&fx, tag, round, workers, inject);
        if clean {
            assert_eq!(
                report.local_jobs, 0,
                "{tag} round {round}: clean round fell back locally"
            );
            assert!(
                report.range_blocks > 0,
                "{tag} round {round}: no data crossed the wire — \
                 workers read the staged path?"
            );
        }
    }
    assert!(faulted > 0, "forced-fault backstop failed");
}

/// CI variant: 8 workers, 6 rounds (round 0 clean, then randomized).
#[test]
fn soak_quick() {
    soak("quick", 6, 8, 0x50AB_0001);
}

/// Nightly variant: more rounds, same fleet. Run with
/// `cargo test --test distributed_soak -- --ignored`.
#[test]
#[ignore = "long soak; run explicitly (nightly)"]
fn soak_long() {
    soak("long", 24, 8, 0x50AB_0002);
}

// ------------------------------------------------ chaos-proxy rounds

/// Every fault the proxy knows how to inject, in one menu — each
/// connection (and each direction of it) draws independently, so a
/// round mixes healthy, slow, fragmented and dying links.
fn chaos_menu() -> Vec<Fault> {
    vec![
        Fault::None,
        Fault::Latency { ms: 10, jitter_ms: 20 },
        Fault::Split { max_chunk: 7, delay_us: 200 },
        Fault::Blackhole { after_bytes: 2048, hold_ms: 400 },
        Fault::Rst { after_bytes: 4096 },
        Fault::HalfClose { after_bytes: 4096 },
    ]
}

/// Reserve an ephemeral port by bind-then-drop so the proxy can be
/// told the coordinator's address before the coordinator binds it.
/// (Loopback, test-lifetime — the rebind race is acceptable here.)
fn pick_port() -> u16 {
    TcpListener::bind("127.0.0.1:0")
        .unwrap()
        .local_addr()
        .unwrap()
        .port()
}

/// One chaos round: the whole fleet connects through a seeded
/// [`ChaosProxy`]; whatever the schedule breaks, the saved `.fcm`
/// must match the single-process reference byte for byte.
fn chaos_round(
    fx: &Fixture,
    tag: &str,
    round: usize,
    workers: usize,
    seed: u64,
) {
    let work = tmp(&format!("dist_chaos_{tag}_work"));
    std::fs::create_dir_all(&work).unwrap();
    let port = pick_port();
    let upstream: SocketAddr =
        format!("127.0.0.1:{port}").parse().unwrap();
    let mut proxy =
        ChaosProxy::start(upstream, seed, chaos_menu()).unwrap();
    let paddr = proxy.addr().to_string();
    // external workers aimed at the proxy, with a connect-retry
    // window: the coordinator has not bound its port yet
    let mut kids: Vec<Child> = (0..workers)
        .map(|_| {
            Command::new(env!("CARGO_BIN_EXE_repro"))
                .args([
                    "worker",
                    "--connect",
                    &paddr,
                    "--heartbeat-ms",
                    "800",
                    "--connect-retry-ms",
                    "5000",
                ])
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .unwrap()
        })
        .collect();
    let dist = DistOptions {
        workers: 0,
        expect_external: workers,
        bind: format!("127.0.0.1:{port}"),
        accept_ms: 4000,
        chunk_samples: 4,
        heartbeat_ms: 800,
        work_dir: Some(work.clone()),
        distribute_clustering: true,
        ..Default::default()
    };
    let label = format!("{tag} chaos round {round} [seed {seed:#x}]");
    let (model, report) = run_distributed_fit(
        &fx.ds, &fx.labels, &fx.reduce, &fx.est, &fx.dc, &fx.opts, &dist,
    )
    .unwrap_or_else(|e| panic!("{label}: distributed fit failed: {e}"));
    proxy.stop();
    for k in &mut kids {
        let _ = k.kill();
        let _ = k.wait();
    }

    // event-log artifact first, assertions second (CI upload)
    let mut log = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(tmp("dist_soak_events.log"))
        .unwrap();
    writeln!(
        log,
        "=== {label}: proxied_conns={} connected={} lost={} \
         retries={} local_jobs={} range_blocks={}",
        proxy.connections(),
        report.workers_connected,
        report.workers_lost,
        report.retries,
        report.local_jobs,
        report.range_blocks
    )
    .unwrap();
    for e in &report.events {
        writeln!(log, "{e:?}").unwrap();
    }

    assert!(
        proxy.connections() > 0,
        "{label}: no worker ever reached the proxy"
    );
    let path = tmp(&format!("dist_chaos_{tag}_round{round}.fcm"));
    save_model(&path, &model).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    assert_eq!(
        bytes, fx.local_bytes,
        "{label}: chaos-proxied .fcm differs from the single-process \
         artifact (events: {:?})",
        report.events
    );
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_dir_all(&work);
}

fn chaos(tag: &str, rounds: usize, workers: usize, seed: u64) {
    let fx = fixture(&format!("chaos_{tag}"));
    for round in 0..rounds {
        chaos_round(&fx, tag, round, workers, seed + round as u64);
    }
}

/// CI variant: four seeded schedules over a 4-worker proxied fleet.
#[test]
fn chaos_quick() {
    chaos("quick", 4, 4, 0xC4A0_0001);
}

/// Nightly variant: more schedules, bigger fleet.
#[test]
#[ignore = "long chaos soak; run explicitly (nightly)"]
fn chaos_long() {
    chaos("long", 12, 6, 0xC4A0_1001);
}

// ----------------------------------------- coordinator kill + resume

/// The fixture's fit as a CLI config file, so child `repro
/// fit-distributed` processes run the *same* plan (ADR-010 identity
/// is then child-vs-child: resumed run vs uninterrupted run).
fn resume_config() -> ExperimentConfig {
    ExperimentConfig {
        data: DataConfig {
            dims: [8, 9, 7],
            n_samples: 18,
            seed: 33,
            ..Default::default()
        },
        reduce: ReduceConfig {
            method: Method::FastSharded,
            ratio: 10,
            shards: 3,
            ..Default::default()
        },
        estimator: EstimatorConfig {
            cv_folds: 3,
            max_iter: 60,
            ..Default::default()
        },
        stream: StreamConfig { chunk_samples: 4, ..Default::default() },
        dist: DistSettings {
            workers: 3,
            distribute_clustering: true,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn spawn_fit_child(
    cfg_path: &Path,
    save: &Path,
    journal: &Path,
    resume: bool,
) -> Child {
    let mut c = Command::new(env!("CARGO_BIN_EXE_repro"));
    c.arg("fit-distributed")
        .arg("--config")
        .arg(cfg_path)
        .arg("--save")
        .arg(save)
        .arg("--journal")
        .arg(journal)
        .stdout(Stdio::null())
        .stderr(Stdio::null());
    if resume {
        c.arg("--resume").arg(journal);
    }
    c.spawn().unwrap()
}

/// SIGKILL `repro fit-distributed` once its journal reaches a seeded
/// fraction of the reference run's length, then `--resume` and
/// byte-compare against the uninterrupted run.
fn kill_resume(tag: &str, rounds: usize, seed: u64) {
    let dir = tmp(&format!("dist_resume_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("cfg.json");
    std::fs::write(
        &cfg_path,
        resume_config().to_json().to_string_pretty(),
    )
    .unwrap();

    // uninterrupted reference run (also sizes the journal)
    let ref_save = dir.join("ref.fcm");
    let ref_journal = dir.join("ref.fcj");
    let st = spawn_fit_child(&cfg_path, &ref_save, &ref_journal, false)
        .wait()
        .unwrap();
    assert!(st.success(), "{tag}: reference child run failed");
    let ref_bytes = std::fs::read(&ref_save).unwrap();
    let ref_len = std::fs::metadata(&ref_journal).unwrap().len();
    assert!(ref_len > 0, "{tag}: reference run wrote no journal");

    let mut rng = Rng::new(seed);
    for round in 0..rounds {
        let save = dir.join(format!("kill{round}.fcm"));
        let journal = dir.join(format!("kill{round}.fcj"));
        // kill somewhere between 20% and 80% of the journal bytes —
        // early kills exercise requeue-almost-everything, late kills
        // exercise replay-almost-everything
        let frac = 20 + rng.below(61) as u64;
        let threshold = (ref_len * frac / 100).max(1);
        let mut child =
            spawn_fit_child(&cfg_path, &save, &journal, false);
        let deadline = Instant::now() + Duration::from_secs(120);
        let mut killed = false;
        loop {
            if child.try_wait().unwrap().is_some() {
                break; // won the race: resume will replay everything
            }
            let done = std::fs::metadata(&journal)
                .map(|m| m.len())
                .unwrap_or(0);
            if done >= threshold || Instant::now() > deadline {
                let _ = child.kill(); // SIGKILL: no destructors run
                let _ = child.wait();
                killed = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }

        let st = spawn_fit_child(&cfg_path, &save, &journal, true)
            .wait()
            .unwrap();
        assert!(
            st.success(),
            "{tag} round {round}: resumed child run failed"
        );
        let bytes = std::fs::read(&save).unwrap();

        // event-log artifact: the resume accounting from the sidecar
        let sidecar = std::fs::read_to_string(format!(
            "{}.dist.json",
            save.display()
        ))
        .unwrap();
        let v = fastclust::json::parse(&sidecar).unwrap();
        let replayed = v
            .get("replayed_jobs")
            .and_then(|x| x.as_usize())
            .unwrap_or(0);
        let requeued = v
            .get("requeued_jobs")
            .and_then(|x| x.as_usize())
            .unwrap_or(0);
        let mut log = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(tmp("dist_soak_events.log"))
            .unwrap();
        writeln!(
            log,
            "=== {tag} kill/resume round {round}: killed={killed} \
             frac={frac}% replayed={replayed} requeued={requeued}"
        )
        .unwrap();

        assert_eq!(
            bytes, ref_bytes,
            "{tag} round {round}: resumed .fcm differs from the \
             uninterrupted run (killed={killed}, frac={frac}%, \
             replayed={replayed}, requeued={requeued})"
        );
        let _ = std::fs::remove_file(&save);
        let _ = std::fs::remove_file(&journal);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// CI variant: two seeded kill points.
#[test]
fn kill_resume_quick() {
    kill_resume("quick", 2, 0x4B11_0001);
}

/// Nightly variant: six seeded kill points.
#[test]
#[ignore = "long kill/resume soak; run explicitly (nightly)"]
fn kill_resume_long() {
    kill_resume("long", 6, 0x4B11_1001);
}
