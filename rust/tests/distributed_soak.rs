//! Fault soak for the distributed stage-1 parcellation (ADR-009):
//! many rounds of `run_distributed_fit` with `distribute_clustering`
//! on, an 8-worker fleet and a *randomized* fault drawn from a seeded
//! RNG each round (none / kill / drop / corrupt / delay, against a
//! random worker). Every round the saved `.fcm` must be byte-identical
//! to the single-process fast-sharded [`fit_model`] artifact — the
//! fleet size, the arrival order and the injected fault are all
//! scheduling noise by contract.
//!
//! The jobs run in wire mode (`stem = ""`), so workers never see the
//! staged `.fcd` path: every voxel/sample block crosses the socket via
//! FETCH/DATA range serving, which the clean round asserts directly
//! (`range_blocks > 0`, `local_jobs == 0`).
//!
//! Each round appends its event log to
//! `$CARGO_TARGET_TMPDIR/dist_soak_events.log` before asserting, so a
//! CI failure ships the full soak history as an artifact.
//!
//! `soak_quick` runs in the distributed-smoke CI job; the longer
//! `soak_long` variant is `#[ignore]`d for nightly/manual runs:
//! `cargo test --test distributed_soak -- --ignored`.

use std::io::Write;
use std::path::PathBuf;

use fastclust::config::{
    DataConfig, EstimatorConfig, Method, ReduceConfig,
};
use fastclust::coordinator::{
    run_distributed_fit, DistOptions, DistReport, FaultKind, FaultSpec,
};
use fastclust::model::{fit_model, save_model, FitOptions};
use fastclust::rng::Rng;
use fastclust::volume::{MaskedDataset, MorphometryGenerator};

fn tmp(name: &str) -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_TARGET_TMPDIR"));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

struct Fixture {
    ds: MaskedDataset,
    labels: Vec<u8>,
    reduce: ReduceConfig,
    est: EstimatorConfig,
    dc: DataConfig,
    opts: FitOptions,
    local_bytes: Vec<u8>,
}

/// Small cohort, fast-sharded stage 1 with a *pinned* shard count
/// (shards = 0 would resolve from the core count and the plan must be
/// machine-independent here), plus the single-process reference bytes.
fn fixture(tag: &str) -> Fixture {
    let dc = DataConfig {
        dims: [8, 9, 7],
        n_samples: 18,
        seed: 33,
        ..Default::default()
    };
    let (ds, labels) =
        MorphometryGenerator::new(dc.dims).generate(dc.n_samples, dc.seed);
    let reduce = ReduceConfig {
        method: Method::FastSharded,
        ratio: 10,
        shards: 3,
        ..Default::default()
    };
    let est = EstimatorConfig {
        cv_folds: 3,
        max_iter: 60,
        ..Default::default()
    };
    let opts = FitOptions::default();
    let model =
        fit_model(&ds, &labels, &reduce, &est, &dc, &opts).unwrap();
    let path = tmp(&format!("dist_soak_{tag}_local.fcm"));
    save_model(&path, &model).unwrap();
    let local_bytes = std::fs::read(&path).unwrap();
    Fixture { ds, labels, reduce, est, dc, opts, local_bytes }
}

/// Draw this round's fault from the soak RNG: roughly one round in
/// five is clean, the rest spread over the four fault kinds, each
/// aimed at a uniformly random member of the fleet.
fn draw_fault(rng: &mut Rng, workers: usize) -> Option<FaultSpec> {
    let kind = match rng.below(5) {
        0 => return None,
        1 => FaultKind::Kill,
        2 => FaultKind::Drop,
        3 => FaultKind::Corrupt,
        _ => FaultKind::Delay,
    };
    Some(FaultSpec { kind, worker: rng.below(workers) })
}

fn fault_name(f: &Option<FaultSpec>) -> String {
    match f {
        None => "clean".into(),
        Some(s) => format!("{:?}:{}", s.kind, s.worker),
    }
}

/// One soak round: distributed fit with the drawn fault, event log
/// appended to the soak artifact, `.fcm` byte-compared against the
/// reference.
fn soak_round(
    fx: &Fixture,
    tag: &str,
    round: usize,
    workers: usize,
    inject: Option<FaultSpec>,
) -> DistReport {
    let work = tmp(&format!("dist_soak_{tag}_work"));
    std::fs::create_dir_all(&work).unwrap();
    let dist = DistOptions {
        workers,
        chunk_samples: 4,
        heartbeat_ms: 600,
        worker_bin: Some(PathBuf::from(env!("CARGO_BIN_EXE_repro"))),
        work_dir: Some(work.clone()),
        distribute_clustering: true,
        inject: inject.clone(),
        ..Default::default()
    };
    let label = format!("{tag} round {round} [{}]", fault_name(&inject));
    let (model, report) = run_distributed_fit(
        &fx.ds, &fx.labels, &fx.reduce, &fx.est, &fx.dc, &fx.opts, &dist,
    )
    .unwrap_or_else(|e| panic!("{label}: distributed fit failed: {e}"));

    // Event-log artifact first, assertions second: a failed round must
    // still leave its history on disk for the CI upload.
    let mut log = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(tmp("dist_soak_events.log"))
        .unwrap();
    writeln!(
        log,
        "=== {label}: cluster_jobs={} range_blocks={} retries={} \
         local_jobs={} workers_lost={}",
        report.cluster_jobs,
        report.range_blocks,
        report.retries,
        report.local_jobs,
        report.workers_lost
    )
    .unwrap();
    for e in &report.events {
        writeln!(log, "{e:?}").unwrap();
    }

    let path = tmp(&format!("dist_soak_{tag}_round{round}.fcm"));
    save_model(&path, &model).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    assert_eq!(
        bytes, fx.local_bytes,
        "{label}: distributed .fcm differs from the single-process \
         fast-sharded artifact (events: {:?})",
        report.events
    );
    assert_eq!(
        report.cluster_jobs, 3,
        "{label}: stage 1 was not sharded into the pinned shard count"
    );
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_dir_all(&work);
    report
}

fn soak(tag: &str, rounds: usize, workers: usize, seed: u64) {
    let fx = fixture(tag);
    let mut rng = Rng::new(seed);
    let mut faulted = 0usize;
    for round in 0..rounds {
        // Round 0 is forced clean so the range-serving path is
        // asserted unconditionally at least once per soak; the last
        // round is forced faulty if the RNG never injected anything
        // (a soak that only ran clean rounds proves nothing).
        let inject = if round == 0 {
            None
        } else if round + 1 == rounds && faulted == 0 {
            Some(FaultSpec { kind: FaultKind::Kill, worker: 0 })
        } else {
            draw_fault(&mut rng, workers)
        };
        let clean = inject.is_none();
        faulted += usize::from(!clean);
        let report = soak_round(&fx, tag, round, workers, inject);
        if clean {
            assert_eq!(
                report.local_jobs, 0,
                "{tag} round {round}: clean round fell back locally"
            );
            assert!(
                report.range_blocks > 0,
                "{tag} round {round}: no data crossed the wire — \
                 workers read the staged path?"
            );
        }
    }
    assert!(faulted > 0, "forced-fault backstop failed");
}

/// CI variant: 8 workers, 6 rounds (round 0 clean, then randomized).
#[test]
fn soak_quick() {
    soak("quick", 6, 8, 0x50AB_0001);
}

/// Nightly variant: more rounds, same fleet. Run with
/// `cargo test --test distributed_soak -- --ignored`.
#[test]
#[ignore = "long soak; run explicitly (nightly)"]
fn soak_long() {
    soak("long", 24, 8, 0x50AB_0002);
}
