//! Integration suite for the ADR-007 serve front-end: concurrent
//! clients over the binary protocol and the HTTP/JSON gateway must
//! get responses bit-identical to the offline apply-only path while
//! cross-connection micro-batching is coalescing their requests; the
//! connection budget must shed explicitly on both wires; and
//! `GET /metrics` must serve valid JSON that reflects the traffic.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;

use fastclust::config::{
    DataConfig, EstimatorConfig, Method, ReduceConfig,
};
use fastclust::model::{
    fit_model, load_model, save_model, FitOptions, FittedModel,
};
use fastclust::serve::{
    Request, Response, ServeClient, ServeOptions, Server,
};
use fastclust::volume::{FeatureMatrix, MorphometryGenerator};

const N_CLIENTS: usize = 8;

fn tmp(name: &str) -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_TARGET_TMPDIR"));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Fit + persist a small model; returns (path, loaded model, cohort
/// sample-major features) — the offline truth every served response
/// must reproduce bit-for-bit.
fn fixture(
    tag: &str,
) -> (PathBuf, Arc<FittedModel>, Arc<FeatureMatrix>) {
    let dc = DataConfig {
        dims: [8, 9, 7],
        n_samples: 24,
        seed: 11,
        ..Default::default()
    };
    let (ds, y) = MorphometryGenerator::new(dc.dims)
        .generate(dc.n_samples, dc.seed);
    let reduce = ReduceConfig {
        method: Method::Fast,
        ratio: 10,
        ..Default::default()
    };
    let est = EstimatorConfig {
        cv_folds: 3,
        max_iter: 60,
        ..Default::default()
    };
    let model =
        fit_model(&ds, &y, &reduce, &est, &dc, &FitOptions::default())
            .unwrap();
    let path = tmp(&format!("serve_batching_{tag}.fcm"));
    save_model(&path, &model).unwrap();
    let loaded = Arc::new(load_model(&path).unwrap());
    let xs = Arc::new(ds.data().transpose());
    (path, loaded, xs)
}

/// A distinct `(2, p)` block per client, strided over the cohort.
fn client_block(xs: &FeatureMatrix, c: usize) -> FeatureMatrix {
    let rows: Vec<usize> =
        (0..2).map(|i| (c + i * N_CLIENTS) % xs.rows).collect();
    xs.select_rows(&rows)
}

#[test]
fn batched_concurrent_clients_match_offline_bits() {
    let (path, model, xs) = fixture("bin");
    let mut opts = ServeOptions::new(&path);
    opts.workers = 4;
    opts.max_batch = 4; // force multi-batch splits under pipelining
    opts.batch_window_us = 2_000;
    opts.log_path = Some(tmp("serve_batching_bin.log"));
    let handle = Server::start(opts).unwrap();
    let addr = handle.addr();

    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for c in 0..N_CLIENTS {
            let model = model.clone();
            let xs = xs.clone();
            joins.push(scope.spawn(move || {
                let block = client_block(&xs, c);
                let want_p = model.predict_proba(&block).unwrap();
                let want_x = model.compress(&block).unwrap();
                let mut client =
                    ServeClient::connect(addr).unwrap();
                // sequential rounds overlap with the other clients,
                // so the batcher coalesces across connections
                for round in 0..4 {
                    assert_eq!(
                        client.predict(&block).unwrap(),
                        want_p,
                        "client {c} round {round}: batched predict \
                         != offline bits"
                    );
                    assert_eq!(
                        client.compress(&block).unwrap().data,
                        want_x.data,
                        "client {c} round {round}: batched \
                         compress != offline bits"
                    );
                }
                // pipelined burst larger than max_batch: responses
                // must come back in order across batch boundaries
                let rqs: Vec<Request> = (0..9)
                    .map(|_| Request::Predict {
                        model: String::new(),
                        x: block.clone(),
                    })
                    .collect();
                for rs in client.call_pipelined(&rqs).unwrap() {
                    match rs {
                        Response::Probabilities(p) => {
                            assert_eq!(
                                p, want_p,
                                "client {c}: pipelined response \
                                 drifted across a batch boundary"
                            )
                        }
                        other => panic!("client {c}: {other:?}"),
                    }
                }
            }));
        }
        for j in joins {
            j.join().expect("client thread panicked");
        }
    });

    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.connections, N_CLIENTS as u64);
    // per client: 4×(predict+compress) + 9 pipelined = 17
    assert_eq!(stats.requests, (N_CLIENTS * 17) as u64);
    assert_eq!(stats.errors, 0);
    assert!(
        stats.batches <= stats.requests,
        "batches cannot exceed requests"
    );
}

/// Blocking HTTP/1.1 exchange on a persistent connection.
fn http_exchange(
    writer: &mut TcpStream,
    reader: &mut impl BufRead,
    req: &str,
) -> (u16, String) {
    writer.write_all(req.as_bytes()).unwrap();
    let mut head = String::new();
    loop {
        let mut line = String::new();
        assert!(
            reader.read_line(&mut line).unwrap() > 0,
            "connection closed mid-response"
        );
        if line == "\r\n" {
            break;
        }
        head.push_str(&line);
    }
    let status: u16 = head
        .lines()
        .next()
        .and_then(|l| l.split(' ').nth(1))
        .and_then(|s| s.parse().ok())
        .expect("status line");
    let clen: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length")
                .then(|| v.trim().parse().ok())?
        })
        .expect("content-length");
    let mut body = vec![0u8; clen];
    reader.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}

fn predict_body(x: &FeatureMatrix) -> String {
    use std::fmt::Write as _;
    let mut out = String::from("{\"x\":[");
    for r in 0..x.rows {
        if r > 0 {
            out.push(',');
        }
        out.push('[');
        for c in 0..x.cols {
            if c > 0 {
                out.push(',');
            }
            let _ = write!(out, "{}", x.data[r * x.cols + c] as f64);
        }
        out.push(']');
    }
    out.push_str("]}");
    out
}

#[test]
fn http_gateway_concurrent_clients_match_offline_bits() {
    let (path, model, xs) = fixture("http");
    let mut opts = ServeOptions::new(&path);
    opts.workers = 4;
    opts.http_port = Some(0);
    opts.log_path = Some(tmp("serve_batching_http.log"));
    let handle = Server::start(opts).unwrap();
    let http_addr = handle.http_addr().expect("gateway bound");

    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for c in 0..N_CLIENTS {
            let model = model.clone();
            let xs = xs.clone();
            joins.push(scope.spawn(move || {
                let block = client_block(&xs, c);
                let want = model.predict_proba(&block).unwrap();
                let mut writer =
                    TcpStream::connect(http_addr).unwrap();
                writer.set_nodelay(true).unwrap();
                let mut reader =
                    BufReader::new(writer.try_clone().unwrap());
                // model info route first
                let (code, body) = http_exchange(
                    &mut writer,
                    &mut reader,
                    "GET /v1/models HTTP/1.1\r\nHost: t\r\n\r\n",
                );
                assert_eq!(code, 200, "client {c}: info failed");
                let info = fastclust::json::parse(&body).unwrap();
                assert_eq!(
                    info.get("k").unwrap().as_usize().unwrap(),
                    model.header.k
                );
                // keep-alive predict rounds, bit-compared
                let body_json = predict_body(&block);
                let req = format!(
                    "POST /v1/predict HTTP/1.1\r\n\
                     Content-Length: {}\r\n\r\n{}",
                    body_json.len(),
                    body_json
                );
                for round in 0..4 {
                    let (code, body) = http_exchange(
                        &mut writer,
                        &mut reader,
                        &req,
                    );
                    assert_eq!(
                        code, 200,
                        "client {c} round {round}: {body}"
                    );
                    let v =
                        fastclust::json::parse(&body).unwrap();
                    let got: Vec<f32> = v
                        .get("proba")
                        .unwrap()
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|n| n.as_f64().unwrap() as f32)
                        .collect();
                    assert_eq!(
                        got, want,
                        "client {c} round {round}: HTTP JSON path \
                         lost f32 bits"
                    );
                }
            }));
        }
        for j in joins {
            j.join().expect("http client thread panicked");
        }
    });

    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.connections, N_CLIENTS as u64);
    assert_eq!(stats.requests, (N_CLIENTS * 5) as u64);
    assert_eq!(stats.errors, 0);
}

#[test]
fn connection_budget_sheds_on_both_wires() {
    let (path, _, _) = fixture("shed");
    let mut opts = ServeOptions::new(&path);
    opts.workers = 1;
    opts.max_connections = 2;
    opts.http_port = Some(0);
    opts.log_path = Some(tmp("serve_batching_shed.log"));
    let handle = Server::start(opts).unwrap();
    let addr = handle.addr();
    let http_addr = handle.http_addr().unwrap();

    // fill the budget and prove both slots are live
    let mut a = ServeClient::connect(addr).unwrap();
    a.model_info().unwrap();
    let mut b = ServeClient::connect(addr).unwrap();
    b.model_info().unwrap();

    // binary wire: explicit shed frame, surfaced as a client error
    let mut c = ServeClient::connect(addr).unwrap();
    let err = c.model_info().unwrap_err().to_string();
    assert!(
        err.contains("capacity"),
        "expected an explicit shed, got: {err}"
    );

    // http wire: 429 with a JSON error body, then close
    let mut s = TcpStream::connect(http_addr).unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).unwrap();
    let text = String::from_utf8(raw).unwrap();
    assert!(
        text.starts_with("HTTP/1.1 429 "),
        "expected 429, got: {text}"
    );
    assert!(text.contains("capacity"), "429 body names the cause");

    let m = handle.metrics_json();
    assert_eq!(m.get("shed").unwrap().as_u64().unwrap(), 2);
    assert_eq!(m.get("accepted").unwrap().as_u64().unwrap(), 4);

    // shedding freed nothing that was admitted: both live clients
    // still work
    a.model_info().unwrap();
    b.model_info().unwrap();
    drop((a, b));
    handle.shutdown().unwrap();
}

#[test]
fn metrics_endpoint_reflects_traffic() {
    let (path, model, xs) = fixture("metrics");
    let mut opts = ServeOptions::new(&path);
    opts.workers = 2;
    opts.http_port = Some(0);
    let handle = Server::start(opts).unwrap();
    let addr = handle.addr();
    let http_addr = handle.http_addr().unwrap();

    let block = client_block(&xs, 0);
    let want = model.predict_proba(&block).unwrap();
    let mut client = ServeClient::connect(addr).unwrap();
    for _ in 0..5 {
        assert_eq!(client.predict(&block).unwrap(), want);
    }
    drop(client);

    let mut writer = TcpStream::connect(http_addr).unwrap();
    let mut reader =
        BufReader::new(writer.try_clone().unwrap());
    let (code, body) = http_exchange(
        &mut writer,
        &mut reader,
        "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n",
    );
    assert_eq!(code, 200);
    let v = fastclust::json::parse(&body).unwrap();
    assert!(v.get("accepted").unwrap().as_u64().unwrap() >= 2);
    assert!(v.get("requests").unwrap().as_u64().unwrap() >= 5);
    assert_eq!(v.get("errors").unwrap().as_u64().unwrap(), 0);
    assert!(
        v.get("latency_us_p99").unwrap().as_u64().is_some(),
        "latency quantiles present"
    );
    // the default model shows up in the per-model attribution
    assert!(
        v.get("models").unwrap().get("<default>").is_some(),
        "metrics body: {body}"
    );
    // unknown route still errors politely on the same connection
    let (code, _) = http_exchange(
        &mut writer,
        &mut reader,
        "GET /nope HTTP/1.1\r\nHost: t\r\n\r\n",
    );
    assert_eq!(code, 404);
    drop((writer, reader));
    handle.shutdown().unwrap();
}
