//! Integration: the full decoding pipeline across modules — synthetic
//! cohort → lattice → clustering → reduction → CV logistic regression —
//! plus cross-method consistency checks.

use fastclust::config::{EstimatorConfig, Method, ReduceConfig};
use fastclust::coordinator::{run_decoding_pipeline, PipelineBuilder};
use fastclust::volume::MorphometryGenerator;

fn cohort() -> (fastclust::volume::MaskedDataset, Vec<u8>) {
    MorphometryGenerator::new([12, 14, 10]).generate(60, 99)
}

#[test]
fn every_method_runs_end_to_end() {
    let (ds, y) = cohort();
    let est = EstimatorConfig {
        cv_folds: 3,
        max_iter: 60,
        tol: 1e-3,
        ..Default::default()
    };
    for method in [
        Method::Fast,
        Method::RandSingle,
        Method::Single,
        Method::Ward,
        Method::RandomProjection,
        Method::None,
    ] {
        let reduce =
            ReduceConfig { method, k: 0, ratio: 12, seed: 2, shards: 0 };
        let rep = run_decoding_pipeline(&ds, &y, &reduce, &est)
            .unwrap_or_else(|e| panic!("{} failed: {e}", method.name()));
        assert!(
            rep.accuracy > 0.45,
            "{}: accuracy {} below chance band",
            method.name(),
            rep.accuracy
        );
        assert_eq!(rep.fold_accuracies.len(), 3);
    }
}

#[test]
fn pipeline_is_deterministic() {
    let (ds, y) = cohort();
    let reduce = ReduceConfig {
        method: Method::Fast,
        k: 0,
        ratio: 10,
        seed: 5,
        shards: 0,
    };
    let est = EstimatorConfig {
        cv_folds: 4,
        max_iter: 80,
        ..Default::default()
    };
    let a = run_decoding_pipeline(&ds, &y, &reduce, &est).unwrap();
    let b = run_decoding_pipeline(&ds, &y, &reduce, &est).unwrap();
    assert_eq!(a.fold_accuracies, b.fold_accuracies);
    assert_eq!(a.k, b.k);
}

#[test]
fn worker_parallelism_does_not_change_results() {
    let (ds, y) = cohort();
    let reduce = ReduceConfig {
        method: Method::Ward,
        k: 40,
        ratio: 0,
        seed: 1,
        shards: 0,
    };
    let est = EstimatorConfig {
        cv_folds: 4,
        max_iter: 60,
        ..Default::default()
    };
    let seq = PipelineBuilder::new(reduce.clone(), est.clone())
        .workers(1)
        .run(&ds, &y)
        .unwrap();
    let par = PipelineBuilder::new(reduce, est)
        .workers(3)
        .run(&ds, &y)
        .unwrap();
    assert_eq!(seq.fold_accuracies, par.fold_accuracies);
}

#[test]
fn explicit_k_is_honored_across_methods() {
    let (ds, y) = cohort();
    let est = EstimatorConfig {
        cv_folds: 3,
        max_iter: 40,
        ..Default::default()
    };
    for method in [Method::Fast, Method::Ward, Method::RandomProjection] {
        let reduce =
            ReduceConfig { method, k: 33, ratio: 0, seed: 7, shards: 0 };
        let rep = run_decoding_pipeline(&ds, &y, &reduce, &est).unwrap();
        assert_eq!(rep.k, 33, "{}", method.name());
    }
}
