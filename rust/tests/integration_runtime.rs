//! Integration: the three-layer AOT path. Requires the `pjrt` cargo
//! feature AND the vendored `xla` crate (`--cfg fastclust_has_xla`,
//! see rust/src/runtime/mod.rs) plus `make artifacts` (the Makefile
//! test target guarantees this ordering). With `pjrt` alone the stub
//! runtime is compiled and these tests are skipped — that build is
//! exercised by CI's feature-matrix job.
#![cfg(all(feature = "pjrt", fastclust_has_xla))]
//!
//! Verifies that the PJRT-executed HLO artifacts agree numerically with
//! the native rust implementations — the cross-layer correctness
//! contract (python pytest establishes kernel == oracle; these tests
//! establish rust-native == rust-loaded-oracle; transitively all three
//! agree).

use std::path::PathBuf;
use std::sync::Arc;

use fastclust::estimators::{LogisticRegression, LogregBackend};
use fastclust::rng::Rng;
use fastclust::runtime::Runtime;
use fastclust::volume::FeatureMatrix;

fn runtime() -> Arc<Runtime> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Arc::new(Runtime::new(&dir).expect("run `make artifacts` first"))
}

#[test]
fn smoke_artifact_matches_manifest_golden() {
    let rt = runtime();
    let exe = rt.executable("smoke_matmul_2x2").unwrap();
    let out = exe
        .run(&[
            vec![1.0f32, 2.0, 3.0, 4.0].into(),
            vec![1.0f32; 4].into(),
        ])
        .unwrap();
    assert_eq!(out[0].as_f32().unwrap(), &[5.0, 5.0, 9.0, 9.0]);
}

#[test]
fn pjrt_logreg_step_matches_native_gradient() {
    let rt = runtime();
    let mut rng = Rng::new(31);
    let (n, k) = (100, 64);
    let mut x = FeatureMatrix::zeros(n, k);
    rng.fill_normal(&mut x.data);
    let y: Vec<f32> = (0..n).map(|_| (rng.f64() < 0.5) as u8 as f32).collect();

    let native = LogisticRegression {
        max_iter: 0, // evaluate at w=0 only
        ..Default::default()
    };
    let pjrt = LogisticRegression {
        max_iter: 0,
        backend: LogregBackend::Runtime(rt),
        ..Default::default()
    };
    // max_iter=0 -> fit returns after the first loss/grad eval at 0
    let fn_ = native.fit(&x, &y).unwrap();
    let fp = pjrt.fit(&x, &y).unwrap();
    assert!(
        (fn_.loss - fp.loss).abs() < 1e-4,
        "loss native {} vs pjrt {}",
        fn_.loss,
        fp.loss
    );
    assert!(
        (fn_.grad_norm - fp.grad_norm).abs() < 1e-4,
        "grad norm native {} vs pjrt {}",
        fn_.grad_norm,
        fp.grad_norm
    );
}

#[test]
fn pjrt_logreg_full_fit_agrees_with_native() {
    let rt = runtime();
    let mut rng = Rng::new(32);
    let (n, k) = (80, 32);
    let mut x = FeatureMatrix::zeros(n, k);
    rng.fill_normal(&mut x.data);
    // separable-ish labels from a random hyperplane
    let w_true: Vec<f32> = (0..k).map(|_| rng.normal32()).collect();
    let y: Vec<f32> = (0..n)
        .map(|i| {
            let z: f32 =
                x.row(i).iter().zip(&w_true).map(|(a, b)| a * b).sum();
            (z > 0.0) as u8 as f32
        })
        .collect();

    let native = LogisticRegression {
        tol: 1e-5,
        max_iter: 300,
        ..Default::default()
    };
    let pjrt = LogisticRegression {
        tol: 1e-5,
        max_iter: 300,
        backend: LogregBackend::Runtime(rt),
        ..Default::default()
    };
    let fit_n = native.fit(&x, &y).unwrap();
    let fit_p = pjrt.fit(&x, &y).unwrap();
    // both converged to the same optimum of the same strictly convex
    // objective
    assert!((fit_n.loss - fit_p.loss).abs() < 1e-3);
    for j in 0..k {
        assert!(
            (fit_n.w[j] - fit_p.w[j]).abs() < 5e-2,
            "w[{j}] native {} vs pjrt {}",
            fit_n.w[j],
            fit_p.w[j]
        );
    }
    let acc_n = LogisticRegression::accuracy(&fit_n, &x, &y);
    let acc_p = LogisticRegression::accuracy(&fit_p, &x, &y);
    assert_eq!(acc_n, acc_p);
}

#[test]
fn fused_gd_artifact_converges_to_native_optimum() {
    let rt = runtime();
    let mut rng = Rng::new(35);
    let (n, k) = (120, 48);
    let mut x = FeatureMatrix::zeros(n, k);
    rng.fill_normal(&mut x.data);
    let w_true: Vec<f32> = (0..k).map(|_| rng.normal32()).collect();
    let y: Vec<f32> = (0..n)
        .map(|i| {
            let z: f32 =
                x.row(i).iter().zip(&w_true).map(|(a, b)| a * b).sum();
            (z > 0.0) as u8 as f32
        })
        .collect();
    let lr = LogisticRegression {
        lambda: 1e-2,
        tol: 1e-4,
        max_iter: 3000,
        ..Default::default()
    };
    let native = lr.fit(&x, &y).unwrap();
    let fused = lr.fit_fused(&rt, &x, &y).unwrap();
    assert!(
        (native.loss - fused.loss).abs() < 5e-3,
        "loss native {} vs fused {}",
        native.loss,
        fused.loss
    );
    let acc_n = LogisticRegression::accuracy(&native, &x, &y);
    let acc_f = LogisticRegression::accuracy(&fused, &x, &y);
    assert!(
        (acc_n - acc_f).abs() < 0.03,
        "accuracy native {acc_n} vs fused {acc_f}"
    );
    // the whole point: far fewer PJRT dispatches than gradient steps
    assert!(
        fused.evals * 16 <= fused.iters.max(64),
        "fused path did not amortize dispatches: {} evals for {} iters",
        fused.evals,
        fused.iters
    );
}

#[test]
fn reduce_apply_artifact_matches_native_cluster_means() {
    let rt = runtime();
    let exe = rt.executable("reduce_apply_p4096_k512_n64").unwrap();
    let (p, k, n) = (4096usize, 512usize, 64usize);
    let mut rng = Rng::new(33);
    // random labels covering all clusters
    let mut labels: Vec<u32> =
        (0..p).map(|_| rng.below(k) as u32).collect();
    for c in 0..k {
        labels[c] = c as u32;
    }
    let mut onehot = vec![0.0f32; p * k];
    for (i, &l) in labels.iter().enumerate() {
        onehot[i * k + l as usize] = 1.0;
    }
    let mut x = vec![0.0f32; p * n];
    for v in &mut x {
        *v = rng.normal32();
    }
    let out = exe
        .run(&[onehot.into(), x.clone().into()])
        .unwrap();
    let got = out[0].as_f32().unwrap();

    // native cluster means
    let fm = FeatureMatrix::from_vec(p, n, x).unwrap();
    let lab = fastclust::cluster::Labels::new(labels, k).unwrap();
    let red = fastclust::reduce::ClusterReduce::from_labels(&lab);
    use fastclust::reduce::Reducer;
    let want = red.reduce(&fm);
    assert_eq!(got.len(), want.data.len());
    for i in 0..got.len() {
        assert!(
            (got[i] - want.data[i]).abs() < 1e-3,
            "mismatch at {i}: pjrt {} vs native {}",
            got[i],
            want.data[i]
        );
    }
}

#[test]
fn pairwise_sqdist_artifact_matches_native() {
    let rt = runtime();
    let exe = rt.executable("pairwise_sqdist_n256_d2048").unwrap();
    let (n, d) = (256usize, 2048usize);
    let mut rng = Rng::new(34);
    let mut s = vec![0.0f32; n * d];
    for v in &mut s {
        *v = rng.normal32();
    }
    let out = exe.run(&[s.clone().into()]).unwrap();
    let got = out[0].as_f32().unwrap();
    // spot-check a handful of entries against the direct computation
    for &(a, b) in &[(0usize, 1usize), (5, 200), (255, 0), (100, 100)] {
        let mut want = 0.0f64;
        for c in 0..d {
            let diff = (s[a * d + c] - s[b * d + c]) as f64;
            want += diff * diff;
        }
        let gotv = got[a * n + b] as f64;
        assert!(
            (gotv - want).abs() < 1e-1 * want.max(1.0),
            "d({a},{b}): pjrt {gotv} vs native {want}"
        );
    }
}
