//! Integration suite for the zero-copy model fleet (ADR-008): the
//! mmap-backed `.fcm` loader and the byte-budget [`ModelRegistry`]
//! behind `repro serve`.
//!
//! Pins the PR's acceptance criteria:
//!
//! * a cold open of a multi-MB artifact validates O(header) payload
//!   bytes — observed through [`MappedModel`]'s residency stats, the
//!   registry's `stats_json`, and the live `GET /metrics` endpoint;
//! * every concurrently resident model serves predictions
//!   bit-identical to the offline apply-only path on the same file;
//! * rename-replacing a model under concurrent predict traffic is
//!   atomic: every response matches one of the two versions exactly,
//!   the new bytes win eventually, and nothing errors.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use fastclust::config::{
    DataConfig, EstimatorConfig, Method, ReduceConfig,
};
use fastclust::json;
use fastclust::model::{
    crc32, fit_model, load_model, open_model, save_model, FitOptions,
    FittedModel,
};
use fastclust::serve::{
    ModelRegistry, Request, Response, ServeClient, ServeOptions,
    Server,
};
use fastclust::volume::{FeatureMatrix, MorphometryGenerator};

fn scratch(tag: &str) -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_TARGET_TMPDIR"))
        .join(format!("model_registry_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The shared cohort every model in this suite is fitted on (same
/// mask ⇒ same request width, so versions are swappable in place).
fn cohort() -> (
    fastclust::volume::MaskedDataset,
    Vec<u8>,
    DataConfig,
) {
    let dc = DataConfig {
        dims: [10, 11, 9],
        n_samples: 36,
        seed: 17,
        ..Default::default()
    };
    let (ds, y) = MorphometryGenerator::new(dc.dims)
        .generate(dc.n_samples, dc.seed);
    (ds, y, dc)
}

/// Fit a variant of the shared cohort's model. `sgd_epochs` and
/// `max_iter` steer the weights so variants disagree on purpose;
/// different `note` lengths guarantee the files differ in length
/// (stamp changes survive coarse mtime granularity).
fn fit_variant(
    sgd_epochs: usize,
    max_iter: usize,
    note: &str,
) -> FittedModel {
    let (ds, y, dc) = cohort();
    let reduce = ReduceConfig {
        method: Method::Fast,
        ratio: 10,
        ..Default::default()
    };
    let est = EstimatorConfig {
        cv_folds: 3,
        max_iter,
        ..Default::default()
    };
    let opts = FitOptions {
        sgd_epochs,
        sgd_chunk: 8,
        note: note.to_string(),
    };
    fit_model(&ds, &y, &reduce, &est, &dc, &opts).unwrap()
}

/// Write `bytes` at `path` through a same-directory rename — the
/// deploy discipline the mmap safety contract requires.
fn write_replace(path: &Path, bytes: &[u8]) {
    let tmp = path.with_extension("fcm.tmp");
    std::fs::write(&tmp, bytes).unwrap();
    std::fs::rename(&tmp, path).unwrap();
}

/// Byte offset of the `END ` section inside a canonical `.fcm`.
fn end_offset(bytes: &[u8]) -> usize {
    let mut off = 8; // magic
    loop {
        let tag = &bytes[off..off + 4];
        let len = u64::from_le_bytes(
            bytes[off + 4..off + 12].try_into().unwrap(),
        ) as usize;
        if tag == b"END " {
            return off;
        }
        off += 4 + 8 + len + 4;
    }
}

/// Splice an unknown `PAD0` section of `mb` MiB before `END `,
/// producing a well-formed multi-MB artifact whose bulk no decode
/// path ever needs — the probe for O(header) cold opens.
fn pad_artifact(path: &Path, mb: usize) {
    let bytes = std::fs::read(path).unwrap();
    let end = end_offset(&bytes);
    let payload = vec![0xA5u8; mb << 20];
    let mut out = Vec::with_capacity(bytes.len() + payload.len() + 16);
    out.extend_from_slice(&bytes[..end]);
    out.extend_from_slice(b"PAD0");
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&payload);
    out.extend_from_slice(&crc32(&payload).to_le_bytes());
    out.extend_from_slice(&bytes[end..]);
    write_replace(path, &out);
}

/// A `(rows, p)` request block drawn from the cohort.
fn block(rows: usize) -> FeatureMatrix {
    let (ds, _, _) = cohort();
    let xs = ds.data().transpose();
    xs.select_rows(&(0..rows.min(xs.rows)).collect::<Vec<_>>())
}

// ------------------------------------------------- lazy residency

#[test]
fn cold_open_of_multi_mb_artifact_is_o_header() {
    let dir = scratch("lazy");
    let path = dir.join("padded.fcm");
    save_model(&path, &fit_variant(0, 60, "padded")).unwrap();
    pad_artifact(&path, 4);

    let m = open_model(&path).unwrap();
    assert!(m.file_len() > 4 << 20, "file: {} bytes", m.file_len());
    // the probe: only HEAD's payload has been CRC'd and decoded
    assert!(
        m.validated_payload_bytes() < 4096,
        "cold open validated {} payload bytes",
        m.validated_payload_bytes()
    );
    assert!(
        m.resident_bytes() < 16 << 10,
        "cold open resident: {} bytes",
        m.resident_bytes()
    );
    assert_eq!(m.header().note, "padded");

    // streaming loader agrees the padded artifact is valid, and is
    // the offline truth the mapped apply path must reproduce
    let offline = load_model(&path).unwrap();
    let x = block(5);
    assert_eq!(
        m.predict_proba(&x).unwrap(),
        offline.predict_proba(&x).unwrap(),
        "mapped predict != streaming predict"
    );
    // predict touched REDU + FOLD, never the 4 MiB pad
    assert!(
        m.validated_payload_bytes() < 1 << 20,
        "predict validated {} payload bytes",
        m.validated_payload_bytes()
    );
    // a deep sweep does touch everything, pad included
    m.validate_all_sections().unwrap();
    assert!(m.validated_payload_bytes() > 4 << 20);

    // the same laziness, observed through registry stats
    let reg = ModelRegistry::new(1 << 30);
    reg.get_or_load(&path).unwrap();
    let stats = reg.stats_json();
    let per = stats
        .get("models")
        .unwrap()
        .get(&path.display().to_string())
        .unwrap();
    let validated = per
        .get("validated_payload_bytes")
        .unwrap()
        .as_u64()
        .unwrap();
    let file = per.get("file_bytes").unwrap().as_u64().unwrap();
    assert!(
        validated < 4096 && file > 4 << 20,
        "registry stats: validated {validated} of {file} bytes"
    );
}

// ------------------------------------- concurrent resident models

#[test]
fn resident_models_serve_bit_identical_answers() {
    let dir = scratch("fleet");
    // batch vs 4-epoch SGD vs 8-epoch SGD: three sets of weights
    // that cannot coincide
    let specs: [(&str, usize, usize); 3] = [
        ("a.fcm", 0, 60),
        ("b.fcm", 4, 60),
        ("c.fcm", 8, 60),
    ];
    let mut truths = Vec::new();
    let x = block(6);
    for (name, sgd, iters) in specs {
        let path = dir.join(name);
        save_model(&path, &fit_variant(sgd, iters, name)).unwrap();
        let offline = load_model(&path).unwrap();
        truths.push((
            name.to_string(),
            offline.predict_proba(&x).unwrap(),
        ));
    }
    // the fleet must actually disagree, or identity proves nothing
    assert_ne!(truths[0].1, truths[1].1);
    assert_ne!(truths[0].1, truths[2].1);
    assert_ne!(truths[1].1, truths[2].1);

    let mut opts = ServeOptions::new(dir.join("a.fcm"));
    opts.workers = 2;
    let handle = Server::start(opts).unwrap();
    let mut client = ServeClient::connect(handle.addr()).unwrap();
    for round in 0..3 {
        // one pipelined burst across all three models, so they are
        // resident — and answering — concurrently
        let rqs: Vec<Request> = truths
            .iter()
            .map(|(name, _)| Request::Predict {
                // "" routes to the default model, which is a.fcm
                model: if name == "a.fcm" {
                    String::new()
                } else {
                    name.clone()
                },
                x: x.clone(),
            })
            .collect();
        let responses = client.call_pipelined(&rqs).unwrap();
        for ((name, want), got) in truths.iter().zip(responses) {
            match got {
                Response::Probabilities(p) => assert_eq!(
                    &p, want,
                    "round {round}: served {name} != offline"
                ),
                other => panic!("{name}: {other:?}"),
            }
        }
    }
    // model-info on a named model resolves the same registry entry
    let info = client.model_info_named("b.fcm").unwrap();
    assert_eq!(
        info.get("note").unwrap().as_str().unwrap(),
        "b.fcm"
    );
    drop(client);
    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.errors, 0);
}

// --------------------------------------------- hot reload, raced

#[test]
fn hot_reload_under_concurrent_predict_traffic() {
    let dir = scratch("reload");
    let default = dir.join("default.fcm");
    save_model(&default, &fit_variant(0, 60, "default")).unwrap();
    let hot = dir.join("hot.fcm");

    // two versions with different weights and different lengths
    let v1 = fit_variant(0, 60, "v1");
    let v2 = fit_variant(4, 60, "v2-with-a-longer-note");
    let bytes = |m: &FittedModel| {
        let p = dir.join("stage.fcm");
        save_model(&p, m).unwrap();
        std::fs::read(&p).unwrap()
    };
    let (b1, b2) = (bytes(&v1), bytes(&v2));
    let x = block(4);
    let want1 = v1.predict_proba(&x).unwrap();
    let want2 = v2.predict_proba(&x).unwrap();
    assert_ne!(want1, want2, "versions must disagree");

    write_replace(&hot, &b1);
    let mut opts = ServeOptions::new(&default);
    opts.workers = 2;
    let handle = Server::start(opts).unwrap();
    let addr = handle.addr();

    let stop = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for c in 0..4 {
            let stop = stop.clone();
            let (x, want1, want2) = (&x, &want1, &want2);
            joins.push(scope.spawn(move || {
                let mut client = ServeClient::connect(addr).unwrap();
                let mut seen = [false, false];
                while !stop.load(Ordering::Relaxed) {
                    let rs = client
                        .call_pipelined(&[Request::Predict {
                            model: "hot.fcm".into(),
                            x: x.clone(),
                        }])
                        .unwrap();
                    match &rs[0] {
                        Response::Probabilities(p) if p == want1 => {
                            seen[0] = true;
                        }
                        Response::Probabilities(p) if p == want2 => {
                            seen[1] = true;
                        }
                        other => panic!(
                            "client {c}: response matches neither \
                             version: {other:?}"
                        ),
                    }
                }
                seen
            }));
        }
        // rename-replace the artifact under the live traffic
        for flip in 0..6 {
            std::thread::sleep(Duration::from_millis(25));
            write_replace(
                &hot,
                if flip % 2 == 0 { &b2 } else { &b1 },
            );
        }
        write_replace(&hot, &b2);
        std::thread::sleep(Duration::from_millis(25));
        stop.store(true, Ordering::Relaxed);
        let mut any_v1 = false;
        for j in joins {
            let seen = j.join().expect("predict thread panicked");
            any_v1 |= seen[0];
        }
        assert!(any_v1, "no thread ever saw v1 — race never ran");
    });

    // the final bytes win: a fresh client converges on v2
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut client = ServeClient::connect(addr).unwrap();
    loop {
        let rs = client
            .call_pipelined(&[Request::Predict {
                model: "hot.fcm".into(),
                x: x.clone(),
            }])
            .unwrap();
        match &rs[0] {
            Response::Probabilities(p) if *p == want2 => break,
            Response::Probabilities(p) => assert_eq!(
                p, &want1,
                "post-swap response matches neither version"
            ),
            other => panic!("post-swap: {other:?}"),
        }
        assert!(
            Instant::now() < deadline,
            "server never converged on the replaced bytes"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    drop(client);
    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.errors, 0, "reload race produced errors");
}

// ------------------------------------------------- GET /metrics

/// Blocking HTTP/1.1 exchange on a persistent connection.
fn http_exchange(
    writer: &mut TcpStream,
    reader: &mut impl BufRead,
    req: &str,
) -> (u16, String) {
    writer.write_all(req.as_bytes()).unwrap();
    let mut head = String::new();
    loop {
        let mut line = String::new();
        assert!(
            reader.read_line(&mut line).unwrap() > 0,
            "connection closed mid-response"
        );
        if line == "\r\n" {
            break;
        }
        head.push_str(&line);
    }
    let status: u16 = head
        .lines()
        .next()
        .and_then(|l| l.split(' ').nth(1))
        .and_then(|s| s.parse().ok())
        .expect("status line");
    let clen: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length")
                .then(|| v.trim().parse().ok())?
        })
        .expect("content-length");
    let mut body = vec![0u8; clen];
    reader.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}

#[test]
fn metrics_endpoint_reports_lazy_residency() {
    let dir = scratch("metrics");
    let path = dir.join("padded.fcm");
    save_model(&path, &fit_variant(0, 60, "padded")).unwrap();
    pad_artifact(&path, 4);

    let mut opts = ServeOptions::new(&path);
    opts.workers = 1;
    opts.http_port = Some(0);
    let handle = Server::start(opts).unwrap();
    let http_addr = handle.http_addr().unwrap();

    let mut writer = TcpStream::connect(http_addr).unwrap();
    let mut reader =
        BufReader::new(writer.try_clone().unwrap());
    let (code, body) = http_exchange(
        &mut writer,
        &mut reader,
        "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n",
    );
    assert_eq!(code, 200);
    let v = json::parse(&body).unwrap();
    let per = v
        .get("registry")
        .unwrap()
        .get("models")
        .unwrap()
        .get(&path.display().to_string())
        .unwrap();
    let validated = per
        .get("validated_payload_bytes")
        .unwrap()
        .as_u64()
        .unwrap();
    let file = per.get("file_bytes").unwrap().as_u64().unwrap();
    assert!(
        validated < 4096,
        "eager server start validated {validated} payload bytes"
    );
    assert!(file > 4 << 20, "metrics file_bytes: {file}");

    // traffic touches REDU + FOLD but still never the pad
    let offline = load_model(&path).unwrap();
    let x = block(3);
    let mut client = ServeClient::connect(handle.addr()).unwrap();
    assert_eq!(
        client.predict(&x).unwrap(),
        offline.predict_proba(&x).unwrap()
    );
    drop(client);
    let (code, body) = http_exchange(
        &mut writer,
        &mut reader,
        "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n",
    );
    assert_eq!(code, 200);
    let v = json::parse(&body).unwrap();
    let reg = v.get("registry").unwrap();
    let resident =
        reg.get("resident_bytes").unwrap().as_u64().unwrap();
    assert!(
        resident > 0 && resident < 1 << 20,
        "post-traffic resident_bytes: {resident}"
    );
    assert!(
        reg.get("hits").unwrap().as_u64().unwrap() > 0,
        "predict traffic must hit the resident mapping"
    );
    drop(writer);
    handle.shutdown().unwrap();
}
