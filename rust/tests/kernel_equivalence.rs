//! ADR-005 determinism contract, enforced: the portable and AVX2
//! kernel paths must be **bit-identical** to each other for every
//! kernel, across sizes covering every remainder class
//! `len % LANES ∈ 0..LANES`; element-wise kernels and the
//! scatter-accumulate reduce must additionally be bit-identical to
//! the pre-refactor scalar references (their per-element operations
//! are unchanged and order-preserving); the lane-accumulated
//! reductions (dot, sqdist, GEMV) must agree with an f64 oracle to
//! tight tolerance (the lane split reassociates the f32 sum on
//! purpose — that is the speedup).
//!
//! The `model_roundtrip` suite keeps asserting the `.fcm` fit/apply
//! bit-for-bit guarantees end-to-end on top of these kernels; this
//! file pins the layer underneath it.
//!
//! CI runs this suite twice: on the stock target and with
//! `RUSTFLAGS="-C target-cpu=native"`, so autovectorization of the
//! portable path can never drift it away from the AVX2 path.

use fastclust::kernels::{self, portable, reference, LANES};
use fastclust::rng::Rng;

/// Sizes covering every `len % LANES` remainder class, plus block
/// boundaries and a couple of long tails.
fn test_lens() -> Vec<usize> {
    let mut lens: Vec<usize> = (0..=2 * LANES + 1).collect();
    lens.extend([63, 64, 65, 100, 127, 128, 129, 255, 256, 1000]);
    lens
}

fn random_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; len];
    rng.fill_normal(&mut v);
    v
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: bit mismatch at {i}: {x} vs {y}"
        );
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_on() -> bool {
    fastclust::kernels::avx2::is_available()
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_on() -> bool {
    false
}

// ---------------------------------------------------------- dot

#[test]
fn dot_portable_avx2_and_dispatch_bit_identical() {
    let mut rng = Rng::new(1);
    for len in test_lens() {
        let a = random_vec(&mut rng, len);
        let b = random_vec(&mut rng, len);
        let pd = portable::dot(&a, &b);
        let dd = kernels::dot(&a, &b);
        assert_eq!(pd.to_bits(), dd.to_bits(), "dispatch, len={len}");
        #[cfg(target_arch = "x86_64")]
        if avx2_on() {
            let ad = fastclust::kernels::avx2::dot(&a, &b);
            assert_eq!(pd.to_bits(), ad.to_bits(), "avx2, len={len}");
        }
    }
}

#[test]
fn dot_matches_f64_oracle_to_tolerance() {
    let mut rng = Rng::new(2);
    for len in test_lens() {
        let a = random_vec(&mut rng, len);
        let b = random_vec(&mut rng, len);
        let oracle: f64 = a
            .iter()
            .zip(&b)
            .map(|(&x, &y)| x as f64 * y as f64)
            .sum();
        let got = kernels::dot(&a, &b) as f64;
        let seq = reference::dot_seq(&a, &b) as f64;
        let tol = 1e-4 * (1.0 + oracle.abs() + len as f64 * 1e-3);
        assert!(
            (got - oracle).abs() < tol,
            "len={len}: kernel {got} vs oracle {oracle}"
        );
        assert!(
            (seq - oracle).abs() < tol,
            "len={len}: reference {seq} vs oracle {oracle}"
        );
    }
}

// ------------------------------------------------------- sqdist

#[test]
fn sqdist_portable_avx2_and_dispatch_bit_identical() {
    let mut rng = Rng::new(3);
    for len in test_lens() {
        let a = random_vec(&mut rng, len);
        let b = random_vec(&mut rng, len);
        let pd = portable::sqdist(&a, &b);
        let dd = kernels::sqdist(&a, &b);
        assert_eq!(pd.to_bits(), dd.to_bits(), "dispatch, len={len}");
        #[cfg(target_arch = "x86_64")]
        if avx2_on() {
            let ad = fastclust::kernels::avx2::sqdist(&a, &b);
            assert_eq!(pd.to_bits(), ad.to_bits(), "avx2, len={len}");
        }
        // the reference agrees to tolerance (it reassociates)
        let seq = reference::sqdist_seq(&a, &b);
        let tol = 1e-3 * (1.0 + seq.abs());
        assert!((pd - seq).abs() < tol, "len={len}: {pd} vs {seq}");
    }
}

// -------------------------------------------- element-wise kernels

#[test]
fn elementwise_kernels_bit_identical_to_references() {
    let mut rng = Rng::new(4);
    for len in test_lens() {
        let src = random_vec(&mut rng, len);
        let init = random_vec(&mut rng, len);
        let a = 0.37f32;

        let mut k1 = init.clone();
        let mut r1 = init.clone();
        kernels::acc_add(&mut k1, &src);
        reference::acc_add_seq(&mut r1, &src);
        assert_bits_eq(&k1, &r1, "acc_add");

        let mut k2 = init.clone();
        let mut r2 = init.clone();
        kernels::axpy(&mut k2, a, &src);
        reference::axpy_seq(&mut r2, a, &src);
        assert_bits_eq(&k2, &r2, "axpy");

        let mut k3 = vec![0.0f32; len];
        let mut r3 = vec![0.0f32; len];
        kernels::scale_from(&mut k3, &src, a);
        reference::scale_from_seq(&mut r3, &src, a);
        assert_bits_eq(&k3, &r3, "scale_from");

        // scale and scale_by against their obvious scalar spec
        let mut k4 = init.clone();
        kernels::scale(&mut k4, a);
        let spec4: Vec<f32> = init.iter().map(|v| v * a).collect();
        assert_bits_eq(&k4, &spec4, "scale");

        let mut k5 = init.clone();
        kernels::scale_by(&mut k5, &src);
        let spec5: Vec<f32> =
            init.iter().zip(&src).map(|(v, s)| v * s).collect();
        assert_bits_eq(&k5, &spec5, "scale_by");

        assert_eq!(
            kernels::max_abs(&src).to_bits(),
            reference::max_abs_seq(&src).to_bits(),
            "max_abs"
        );
    }
}

#[cfg(target_arch = "x86_64")]
#[test]
fn elementwise_kernels_portable_vs_avx2_bit_identical() {
    if !avx2_on() {
        return;
    }
    use fastclust::kernels::avx2;
    let mut rng = Rng::new(5);
    for len in test_lens() {
        let src = random_vec(&mut rng, len);
        let init = random_vec(&mut rng, len);
        let a = -1.62f32;

        let mut pp = init.clone();
        let mut vv = init.clone();
        portable::acc_add(&mut pp, &src);
        avx2::acc_add(&mut vv, &src);
        assert_bits_eq(&pp, &vv, "acc_add");

        let mut pp = init.clone();
        let mut vv = init.clone();
        portable::axpy(&mut pp, a, &src);
        avx2::axpy(&mut vv, a, &src);
        assert_bits_eq(&pp, &vv, "axpy");

        let mut pp = init.clone();
        let mut vv = init.clone();
        portable::scale(&mut pp, a);
        avx2::scale(&mut vv, a);
        assert_bits_eq(&pp, &vv, "scale");

        let mut pp = init.clone();
        let mut vv = init.clone();
        portable::scale_by(&mut pp, &src);
        avx2::scale_by(&mut vv, &src);
        assert_bits_eq(&pp, &vv, "scale_by");

        let mut pp = vec![0.0f32; len];
        let mut vv = vec![0.0f32; len];
        portable::scale_from(&mut pp, &src, a);
        avx2::scale_from(&mut vv, &src, a);
        assert_bits_eq(&pp, &vv, "scale_from");
    }
}

// ------------------------------------------------ composite kernels

#[test]
fn gemv_bias_bit_stable_and_near_oracle() {
    let mut rng = Rng::new(6);
    for cols in [1usize, 3, 7, 8, 9, 16, 33, 100] {
        let rows = 17;
        let data = random_vec(&mut rng, rows * cols);
        let w = random_vec(&mut rng, cols);
        let mut out = vec![0.0f32; rows];
        kernels::gemv_bias(&data, cols, &w, 0.5, &mut out);
        // row r equals the dispatched dot kernel exactly
        for r in 0..rows {
            let want = 0.5 + kernels::dot(&data[r * cols..][..cols], &w);
            assert_eq!(out[r].to_bits(), want.to_bits(), "row {r}");
        }
        // and the sequential reference to tolerance
        let mut seq = vec![0.0f32; rows];
        reference::gemv_bias_seq(&data, cols, &w, 0.5, &mut seq);
        for r in 0..rows {
            let tol = 1e-3 * (1.0 + seq[r].abs());
            assert!((out[r] - seq[r]).abs() < tol, "row {r}");
        }
    }
}

#[test]
fn scatter_add_rows_bit_identical_to_reference_across_shapes() {
    let mut rng = Rng::new(7);
    for &(p, k, cols) in
        &[(13usize, 4usize, 1usize), (64, 8, 7), (100, 5, 65), (30, 1, 130)]
    {
        let labels: Vec<u32> =
            (0..p).map(|_| rng.below(k) as u32).collect();
        let x = random_vec(&mut rng, p * cols);
        let mut got = vec![0.0f32; k * cols];
        let mut want = vec![0.0f32; k * cols];
        kernels::scatter_add_rows(&labels, &x, cols, &mut got);
        reference::scatter_add_rows_seq(&labels, &x, cols, &mut want);
        assert_bits_eq(&got, &want, "scatter_add_rows");

        // the sample-major transpose scatter sums identically
        let mut col_out = vec![0.0f32; k];
        let ones = vec![1.0f32; p];
        kernels::scatter_add_cols(&labels, &ones, &mut col_out);
        let total: f32 = col_out.iter().sum();
        assert_eq!(total, p as f32);
    }
}

#[test]
fn scatter_add_rows_multi_block_path_bit_identical() {
    // Force the cache-blocked path to take MULTIPLE column blocks:
    // block = clamp(SCATTER_BLOCK_BYTES/4/k, 64, cols), so k large
    // enough drives block down to 64 while cols = 200 spans four
    // blocks (64 + 64 + 64 + 8) — boundary arithmetic included.
    let k = fastclust::kernels::SCATTER_BLOCK_BYTES / 4 / 64;
    let (p, cols) = (50usize, 200usize);
    let mut rng = Rng::new(11);
    let labels: Vec<u32> = (0..p).map(|_| rng.below(k) as u32).collect();
    let x = random_vec(&mut rng, p * cols);
    let mut got = vec![0.0f32; k * cols];
    let mut want = vec![0.0f32; k * cols];
    kernels::scatter_add_rows(&labels, &x, cols, &mut got);
    reference::scatter_add_rows_seq(&labels, &x, cols, &mut want);
    // compare only touched rows (k·cols is ~13 MB of mostly zeros)
    for &l in &labels {
        let r = l as usize;
        assert_bits_eq(
            &got[r * cols..(r + 1) * cols],
            &want[r * cols..(r + 1) * cols],
            "multi-block row",
        );
    }
    let gs: f64 = got.iter().map(|&v| v as f64).sum();
    let ws: f64 = want.iter().map(|&v| v as f64).sum();
    assert_eq!(gs.to_bits(), ws.to_bits(), "full-buffer checksum");
}

#[test]
fn logreg_row_grad_fuses_exactly_its_parts() {
    let mut rng = Rng::new(8);
    for len in test_lens() {
        let row = random_vec(&mut rng, len);
        let w = random_vec(&mut rng, len);
        let mut gw = vec![0.0f32; len];
        let (z, r) =
            kernels::logreg_row_grad(&row, &w, 0.25, 1.0, &mut gw);
        let z_want = 0.25 + kernels::dot(&row, &w);
        assert_eq!(z.to_bits(), z_want.to_bits(), "len={len}");
        let r_want = kernels::sigmoid(z_want) - 1.0;
        assert_eq!(r.to_bits(), r_want.to_bits(), "len={len}");
        let mut gw_want = vec![0.0f32; len];
        kernels::axpy(&mut gw_want, r_want, &row);
        assert_bits_eq(&gw, &gw_want, "logreg gw");

        // the sequential reference agrees to tolerance
        let mut gw_seq = vec![0.0f32; len];
        let (zs, _) = reference::logreg_row_grad_seq(
            &row, &w, 0.25, 1.0, &mut gw_seq,
        );
        let tol = 1e-3 * (1.0 + zs.abs());
        assert!((z - zs).abs() < tol, "len={len}: {z} vs {zs}");
    }
}

// -------------------------------------------------- determinism

#[test]
fn kernels_are_deterministic_run_to_run() {
    let mut rng = Rng::new(9);
    let a = random_vec(&mut rng, 777);
    let b = random_vec(&mut rng, 777);
    assert_eq!(
        kernels::dot(&a, &b).to_bits(),
        kernels::dot(&a, &b).to_bits()
    );
    assert_eq!(
        kernels::sqdist(&a, &b).to_bits(),
        kernels::sqdist(&a, &b).to_bits()
    );
}
