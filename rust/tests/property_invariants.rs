//! Property-based tests (hand-rolled sweep harness; the offline build
//! carries no proptest). Each property is checked over many seeded
//! random instances; failures print the offending seed so the case can
//! be replayed exactly.
#![allow(clippy::needless_range_loop)] // indexed loops mirror the math

use fastclust::cluster::{
    cluster_counts, AverageLinkage, Clusterer, CompleteLinkage, FastCluster,
    KMeans, RandSingle, SingleLinkage, Ward,
};
use fastclust::graph::{
    connected_components, kruskal_mst, nearest_neighbor_edges, Edge,
    LatticeGraph, UnionFind,
};
use fastclust::reduce::{ClusterReduce, Reducer, SparseRandomProjection};
use fastclust::rng::Rng;
use fastclust::volume::{synthetic_brain_mask, FeatureMatrix, SyntheticCube};

/// Sweep driver: run `prop(seed)` for `n` seeds.
fn for_seeds(n: u64, mut prop: impl FnMut(u64)) {
    for seed in 0..n {
        prop(seed);
    }
}

fn random_instance(
    seed: u64,
) -> (FeatureMatrix, LatticeGraph, usize) {
    let mut rng = Rng::new(seed);
    let dims = [
        4 + rng.below(6),
        4 + rng.below(6),
        3 + rng.below(5),
    ];
    let n = 1 + rng.below(6);
    let ds = SyntheticCube::new(dims, 2.0 + 3.0 * rng.f64(), rng.f64())
        .generate(n, seed ^ 0xDA7A);
    let g = LatticeGraph::from_mask(ds.mask());
    let p = ds.p();
    let k = 2 + rng.below(p / 2);
    (ds.data().clone(), g, k)
}

/// Every clusterer: output is a partition into exactly k non-empty,
/// spatially-connected clusters (k-means exempt from connectivity).
#[test]
fn prop_all_clusterers_produce_valid_k_partitions() {
    for_seeds(8, |seed| {
        let (x, g, k) = random_instance(seed);
        let fast = FastCluster::default();
        let kmeans = KMeans::default();
        let clusterers: Vec<(&dyn Clusterer, bool)> = vec![
            (&fast, true),
            (&RandSingle, true),
            (&SingleLinkage, true),
            (&AverageLinkage, true),
            (&CompleteLinkage, true),
            (&Ward, true),
            (&kmeans, false),
        ];
        for (c, needs_connectivity) in clusterers {
            let labels = c
                .fit(&x, &g, k, seed)
                .unwrap_or_else(|e| panic!("seed {seed} {}: {e}", c.name()));
            assert_eq!(labels.k, k, "seed {seed} {}", c.name());
            assert_eq!(labels.p(), x.rows);
            let counts = cluster_counts(&labels);
            assert!(
                counts.iter().all(|&c| c > 0),
                "seed {seed} {}: empty cluster",
                c.name()
            );
            if needs_connectivity {
                assert_connected(&labels.labels, labels.k, &g, c.name(), seed);
            }
        }
    });
}

fn assert_connected(
    labels: &[u32],
    k: usize,
    g: &LatticeGraph,
    name: &str,
    seed: u64,
) {
    for cl in 0..k as u32 {
        let members: Vec<usize> =
            (0..labels.len()).filter(|&i| labels[i] == cl).collect();
        let mut seen = vec![false; labels.len()];
        let mut stack = vec![members[0]];
        seen[members[0]] = true;
        let mut cnt = 0;
        while let Some(v) = stack.pop() {
            cnt += 1;
            for &nb in g.neighbors(v) {
                let nb = nb as usize;
                if !seen[nb] && labels[nb] == cl {
                    seen[nb] = true;
                    stack.push(nb);
                }
            }
        }
        assert_eq!(
            cnt,
            members.len(),
            "seed {seed} {name}: cluster {cl} disconnected"
        );
    }
}

/// Fast clustering halves the cluster count every round: round count
/// is bounded by ceil(log2(p/k)) + 1.
#[test]
fn prop_fast_clustering_round_bound() {
    for_seeds(10, |seed| {
        let (x, g, k) = random_instance(seed);
        let (_, trace) = FastCluster::default()
            .fit_trace(&x, &g, k, seed)
            .unwrap();
        let p = x.rows;
        let bound =
            ((p as f64 / k as f64).log2().ceil() as usize).max(1) + 1;
        assert!(
            trace.cluster_counts.len() - 1 <= bound,
            "seed {seed}: {} rounds > bound {bound} (p={p}, k={k})",
            trace.cluster_counts.len() - 1
        );
    });
}

/// The 1-NN graph never percolates: every component has >= 2 vertices
/// and component count <= p/2 (Teng & Yao).
#[test]
fn prop_nn_graph_no_singletons() {
    for_seeds(10, |seed| {
        let mut rng = Rng::new(seed ^ 0x99);
        let dims = [5 + rng.below(6), 5 + rng.below(6), 4 + rng.below(4)];
        let mask = synthetic_brain_mask(dims, seed);
        let g = LatticeGraph::from_mask(&mask);
        if g.n_vertices == 0 {
            return;
        }
        let mut wg = g.clone();
        for e in &mut wg.edges {
            e.w = rng.f32() + 1e-5;
        }
        let nn = nearest_neighbor_edges(&wg);
        let (labels, q) = connected_components(wg.n_vertices, &nn);
        let mut sizes = vec![0usize; q];
        for &l in &labels {
            sizes[l as usize] += 1;
        }
        // isolated mask voxels (no lattice neighbors) are legitimate
        // singletons; all others must pair up
        for (c, &s) in sizes.iter().enumerate() {
            if s == 1 {
                let v = labels.iter().position(|&l| l as usize == c).unwrap();
                assert_eq!(
                    wg.degree(v),
                    0,
                    "seed {seed}: non-isolated singleton"
                );
            }
        }
    });
}

/// MST via Kruskal is minimal: no non-tree edge can replace a heavier
/// tree edge on the cycle it closes (verified via the cut property on
/// random small graphs).
#[test]
fn prop_mst_weight_no_better_than_alternative_spanning_trees() {
    for_seeds(12, |seed| {
        let mut rng = Rng::new(seed ^ 0x7777);
        let n = 6 + rng.below(8);
        let mut edges = Vec::new();
        for u in 0..n as u32 {
            for v in (u + 1)..n as u32 {
                if rng.f64() < 0.5 {
                    edges.push(Edge::new(u, v, rng.f32()));
                }
            }
        }
        for u in 0..(n as u32 - 1) {
            edges.push(Edge::new(u, u + 1, 1.0 + rng.f32()));
        }
        let tree = kruskal_mst(n, &edges);
        let total: f64 = tree.iter().map(|e| e.w as f64).sum();
        // random alternative spanning trees are never lighter
        for _ in 0..5 {
            let mut alt_edges = edges.clone();
            rng.shuffle(&mut alt_edges);
            let mut uf = UnionFind::new(n);
            let mut alt_total = 0.0f64;
            let mut cnt = 0;
            for e in &alt_edges {
                if uf.union(e.u, e.v) {
                    alt_total += e.w as f64;
                    cnt += 1;
                }
            }
            if cnt == tree.len() {
                assert!(
                    total <= alt_total + 1e-6,
                    "seed {seed}: MST {total} heavier than random \
                     tree {alt_total}"
                );
            }
        }
    });
}

/// reduce->expand is an idempotent projection that preserves constants
/// and never increases the Frobenius norm.
#[test]
fn prop_cluster_projection_contracts() {
    for_seeds(10, |seed| {
        let (x, g, k) = random_instance(seed);
        let labels = FastCluster::default().fit(&x, &g, k, seed).unwrap();
        let red = ClusterReduce::from_labels(&labels);
        let proj = red.project(&x);
        let proj2 = red.project(&proj);
        for (a, b) in proj.data.iter().zip(&proj2.data) {
            assert!((a - b).abs() < 1e-4, "seed {seed}: not idempotent");
        }
        assert!(
            proj.frob_norm() <= x.frob_norm() * (1.0 + 1e-6),
            "seed {seed}: projection expanded the norm"
        );
    });
}

/// JL property of the sparse RP: E[||Rx||^2] = ||x||^2 within
/// concentration bounds across seeds.
#[test]
fn prop_sparse_rp_norm_concentration() {
    let p = 600;
    let k = 128;
    let mut ratios = Vec::new();
    for_seeds(12, |seed| {
        let rp = SparseRandomProjection::new(p, k, seed);
        let mut rng = Rng::new(seed ^ 0xF0);
        let x: Vec<f32> = (0..p).map(|_| rng.normal32()).collect();
        let xr = rp.reduce_vec(&x);
        let n0: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
        let n1: f64 = xr.iter().map(|&v| (v as f64).powi(2)).sum();
        ratios.push(n1 / n0);
    });
    let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
    assert!(
        (mean - 1.0).abs() < 0.12,
        "norm-ratio mean {mean} drifted from 1 (ratios {ratios:?})"
    );
}

/// Union-find: after any union sequence, n_sets + executed unions = n.
#[test]
fn prop_union_find_counting() {
    for_seeds(20, |seed| {
        let mut rng = Rng::new(seed ^ 0xABCD);
        let n = 10 + rng.below(100);
        let mut uf = UnionFind::new(n);
        let mut effective = 0;
        for _ in 0..n * 2 {
            let a = rng.below(n) as u32;
            let b = rng.below(n) as u32;
            if uf.union(a, b) {
                effective += 1;
            }
        }
        assert_eq!(uf.n_sets() + effective, n, "seed {seed}");
        let labels = uf.labels();
        let mut uniq = labels.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), uf.n_sets(), "seed {seed}");
    });
}
