//! Integration: the out-of-core streaming pipeline (ADR-003) against
//! the in-memory reference, end to end across the volume, reduce,
//! estimator and coordinator layers:
//!
//! * chunked `.fcd` reads reassemble the exact payload;
//! * streaming `ClusterReduce` is bit-identical to the in-memory
//!   reduction for every chunk size;
//! * the full streaming decode (full reservoir, batch solver)
//!   reproduces the in-memory fold accuracies exactly, at any worker
//!   count;
//! * bounded reservoir and SGD partial-fit variants stay within
//!   tolerance of the reference.

use fastclust::cluster::{Clusterer, FastCluster};
use fastclust::config::{
    EstimatorConfig, Method, ReduceConfig, StreamConfig,
};
use fastclust::coordinator::{
    run_decoding_pipeline, run_streaming_decoding, stream_reduce,
};
use fastclust::graph::LatticeGraph;
use fastclust::reduce::{ClusterReduce, Reducer};
use fastclust::volume::{
    load_dataset, save_dataset, FcdReader, MaskedDataset,
    MorphometryGenerator,
};

fn cohort() -> (MaskedDataset, Vec<u8>) {
    MorphometryGenerator::new([10, 12, 9]).generate(40, 7)
}

fn save_cohort(tag: &str) -> (std::path::PathBuf, MaskedDataset, Vec<u8>)
{
    let (ds, y) = cohort();
    let dir = std::env::temp_dir().join("fastclust_stream_equiv");
    std::fs::create_dir_all(&dir).unwrap();
    let stem = dir.join(tag);
    save_dataset(&stem, &ds).unwrap();
    (stem, ds, y)
}

fn reduce_cfg() -> ReduceConfig {
    ReduceConfig {
        method: Method::Fast,
        k: 0,
        ratio: 10,
        seed: 1,
        shards: 0,
    }
}

fn est_cfg() -> EstimatorConfig {
    EstimatorConfig { cv_folds: 4, max_iter: 200, ..Default::default() }
}

#[test]
fn chunked_reader_reassembles_saved_payload() {
    let (stem, ds, _) = save_cohort("reader");
    let full = load_dataset(&stem).unwrap();
    assert_eq!(full.data().data, ds.data().data);
    let mut r = FcdReader::open(&stem).unwrap();
    let mut seen = 0usize;
    for item in r.chunks(6) {
        let sc = item.unwrap();
        for i in 0..sc.x.rows {
            for j in 0..sc.x.cols {
                assert_eq!(
                    sc.x.get(i, j),
                    ds.data().get(i, sc.col0 + j)
                );
            }
        }
        seen += sc.x.cols;
    }
    assert_eq!(seen, ds.n());
}

#[test]
fn streaming_cluster_reduce_bit_identical_any_chunk() {
    let (stem, ds, _) = save_cohort("reduce");
    let graph = LatticeGraph::from_mask(ds.mask());
    let k = (ds.p() / 10).max(2);
    let labels = FastCluster::default()
        .fit(ds.data(), &graph, k, 1)
        .unwrap();
    let red = ClusterReduce::from_labels(&labels);
    let want = red.reduce(ds.data());
    for chunk in [1usize, 5, 16, 40, 1000] {
        let mut r = FcdReader::open(&stem).unwrap();
        let got = stream_reduce(&mut r, &red, chunk).unwrap();
        assert_eq!(got.data, want.data, "chunk={chunk}");
    }
}

#[test]
fn streaming_decode_equals_inmem_for_any_worker_count() {
    let (stem, ds, y) = save_cohort("decode");
    let reduce = reduce_cfg();
    let est = est_cfg();
    let inmem = run_decoding_pipeline(&ds, &y, &reduce, &est).unwrap();
    let stream = StreamConfig {
        enabled: true,
        chunk_samples: 8,
        reservoir: 0,
        sgd_epochs: 0,
    };
    for workers in [1usize, 2, 4] {
        let rep = run_streaming_decoding(
            &stem, &y, &reduce, &est, &stream, workers,
        )
        .unwrap();
        assert_eq!(
            rep.fold_accuracies, inmem.fold_accuracies,
            "workers={workers}"
        );
        assert_eq!(rep.accuracy, inmem.accuracy);
        assert_eq!(rep.k, inmem.k);
    }
}

#[test]
fn chunk_size_does_not_change_streaming_results() {
    let (stem, _, y) = save_cohort("chunksize");
    let reduce = reduce_cfg();
    let est = est_cfg();
    let mut baseline: Option<Vec<f64>> = None;
    for chunk in [1usize, 7, 40] {
        let stream = StreamConfig {
            enabled: true,
            chunk_samples: chunk,
            reservoir: 0,
            sgd_epochs: 0,
        };
        let rep = run_streaming_decoding(
            &stem, &y, &reduce, &est, &stream, 2,
        )
        .unwrap();
        match &baseline {
            None => baseline = Some(rep.fold_accuracies),
            Some(b) => assert_eq!(
                &rep.fold_accuracies, b,
                "chunk={chunk} changed results"
            ),
        }
    }
}

#[test]
fn bounded_reservoir_stays_in_accuracy_band() {
    let (stem, ds, y) = save_cohort("bounded");
    let reduce = reduce_cfg();
    let est = est_cfg();
    let inmem = run_decoding_pipeline(&ds, &y, &reduce, &est).unwrap();
    let stream = StreamConfig {
        enabled: true,
        chunk_samples: 8,
        reservoir: 12, // < n = 40: genuinely subsampled
        sgd_epochs: 0,
    };
    let rep =
        run_streaming_decoding(&stem, &y, &reduce, &est, &stream, 1)
            .unwrap();
    assert_eq!(rep.reservoir_samples, 12);
    // the reservoir bound shows up in the analytic accounting
    assert!(rep.peak_matrix_bytes < rep.inmem_matrix_bytes);
    assert!(
        (rep.accuracy - inmem.accuracy).abs() <= 0.2,
        "bounded accuracy {} vs in-memory {}",
        rep.accuracy,
        inmem.accuracy
    );
}

#[test]
fn sgd_estimator_stays_in_accuracy_band() {
    let (stem, ds, y) = save_cohort("sgd");
    let reduce = reduce_cfg();
    let est = est_cfg();
    let inmem = run_decoding_pipeline(&ds, &y, &reduce, &est).unwrap();
    let stream = StreamConfig {
        enabled: true,
        chunk_samples: 8,
        reservoir: 0,
        sgd_epochs: 150,
    };
    let rep =
        run_streaming_decoding(&stem, &y, &reduce, &est, &stream, 1)
            .unwrap();
    assert!(
        (rep.accuracy - inmem.accuracy).abs() <= 0.2,
        "sgd accuracy {} vs batch {}",
        rep.accuracy,
        inmem.accuracy
    );
}

#[test]
fn streaming_expansion_roundtrip_via_mask() {
    // the reduced representation stays explicit in voxel space:
    // expand() of the streamed reduction equals expand() of the
    // in-memory reduction (piecewise-constant smoothing projection)
    let (stem, ds, _) = save_cohort("expand");
    let graph = LatticeGraph::from_mask(ds.mask());
    let k = (ds.p() / 10).max(2);
    let labels = FastCluster::default()
        .fit(ds.data(), &graph, k, 3)
        .unwrap();
    let red = ClusterReduce::from_labels(&labels);
    let mut r = FcdReader::open(&stem).unwrap();
    let xk_stream = stream_reduce(&mut r, &red, 9).unwrap();
    let back_stream = red.expand(&xk_stream);
    let back_inmem = red.expand(&red.reduce(ds.data()));
    assert_eq!(back_stream.data, back_inmem.data);
}
