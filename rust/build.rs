//! Declares the `fastclust_has_xla` cfg flag (set through
//! `RUSTFLAGS="--cfg fastclust_has_xla"` when the vendored `xla`
//! dependency is uncommented — see `rust/src/runtime/mod.rs`) so the
//! `unexpected_cfgs` lint stays quiet on toolchains that check cfg
//! names, keeping the whole feature matrix warning-free.

fn main() {
    println!("cargo:rustc-check-cfg=cfg(fastclust_has_xla)");
    println!("cargo:rerun-if-changed=build.rs");
}
