//! Bench: regenerate Fig 4 (η distance preservation vs compression
//! ratio, simulated cube + OASIS-like, train/test discipline).
//!
//! ```bash
//! cargo bench --bench fig4_distance
//! ```

use fastclust::bench_harness::{fig4, timeit, write_csv};
use fastclust::config::Method;

fn main() {
    let cfg = fig4::Fig4Config::default();
    println!(
        "Fig 4 driver: cube={:?} oasis={:?} n={} ratios={:?}",
        cfg.cube_dims, cfg.oasis_dims, cfg.n_samples, cfg.ratios
    );
    let (bench, rows) = timeit("fig4_full", 0, 1, || fig4::run(&cfg));
    println!("{}", bench.summary());
    let table = fig4::table(&rows);
    table.print();
    write_csv(&table, std::path::Path::new("results/fig4_distance.csv"))
        .expect("csv");

    // paper shape: ward best among clusterings on distance preservation,
    // RP unbiased, fast close to ward and better than the percolating
    // linkages at the working ratio
    let get = |m: Method, r: f64| {
        rows.iter()
            .find(|x| {
                x.dataset == "oasis-like"
                    && x.method == m
                    && (x.ratio - r).abs() < 1e-9
            })
            .unwrap()
    };
    let rp = get(Method::RandomProjection, 0.1);
    assert!(
        (rp.eta.mean - 1.0).abs() < 0.4,
        "REGRESSION: rp mean eta {} far from 1",
        rp.eta.mean
    );
    let fast = get(Method::Fast, 0.1);
    let avg = get(Method::Average, 0.1);
    println!(
        "fig4 OK: rp mean η {:.3}; fast cv {:.4} (avg-linkage cv {:.4})",
        rp.eta.mean, fast.eta.cv, avg.eta.cv
    );
}
