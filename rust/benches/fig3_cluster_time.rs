//! Bench: regenerate Fig 3 (clustering computation time, k = p/10,
//! n = 100 OASIS-like images) including the BLAS-3 yardstick and the
//! 10-image subsample variant.
//!
//! ```bash
//! cargo bench --bench fig3_cluster_time
//! ```

use fastclust::bench_harness::{fig3, write_csv};

fn main() {
    let cfg = fig3::Fig3Config::default();
    println!(
        "Fig 3 driver: dims={:?} n_images={} ratio={} reps={}",
        cfg.dims, cfg.n_images, cfg.ratio, cfg.reps
    );
    let rows = fig3::run(&cfg);
    let table = fig3::table(&rows);
    table.print();
    write_csv(&table, std::path::Path::new("results/fig3_cluster_time.csv"))
        .expect("csv");
    let secs =
        |label: &str| rows.iter().find(|r| r.label == label).unwrap().secs;
    // the paper's ordering must hold
    assert!(secs("rp") < secs("fast"), "REGRESSION: rp !< fast");
    assert!(secs("fast") < secs("ward"), "REGRESSION: fast !< ward");
    assert!(
        secs("fast") < secs("average"),
        "REGRESSION: fast !< average"
    );
    assert!(
        secs("fast") < secs("complete"),
        "REGRESSION: fast !< complete"
    );
    println!(
        "fig3 OK: fast {:.3}s < ward {:.3}s < (avg {:.3}s | compl {:.3}s)",
        secs("fast"),
        secs("ward"),
        secs("average"),
        secs("complete")
    );
}
