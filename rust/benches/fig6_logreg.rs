//! Bench: regenerate Fig 6 (decoding accuracy vs computation time on
//! the OASIS-like cohort; raw / fast / ward / rp across tolerances).
//!
//! ```bash
//! cargo bench --bench fig6_logreg
//! ```

use fastclust::bench_harness::{fig6, write_csv};
use fastclust::config::Method;

fn main() {
    let cfg = fig6::Fig6Config::default();
    println!(
        "Fig 6 driver: dims={:?} subjects={} ratios={:?} tols={:?}",
        cfg.dims, cfg.n_subjects, cfg.ratios, cfg.tols
    );
    let rows = fig6::run(&cfg);
    let table = fig6::table(&rows);
    table.print();
    write_csv(&table, std::path::Path::new("results/fig6_logreg.csv"))
        .expect("csv");

    // headline: at matched tolerance the compressed fit is faster than
    // raw, with comparable-or-better accuracy
    let best = |m: Method| {
        rows.iter()
            .filter(|r| r.method == m)
            .min_by(|a, b| a.tol.partial_cmp(&b.tol).unwrap())
            .unwrap()
    };
    let raw = best(Method::None);
    let fast = best(Method::Fast);
    assert!(
        fast.fit_secs < raw.fit_secs,
        "REGRESSION: compressed fit {}s !< raw {}s",
        fast.fit_secs,
        raw.fit_secs
    );
    println!(
        "fig6 OK: fast fit {:.2}s (acc {:.3}) vs raw {:.2}s (acc {:.3}) \
         -> speedup {:.1}x",
        fast.fit_secs,
        fast.accuracy,
        raw.fit_secs,
        raw.accuracy,
        raw.fit_secs / fast.fit_secs.max(1e-9)
    );
}
