//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Feature subsampling** (§5: learning clusters on 10 of 100
//!    images): quality (inertia, percolation) and cost across
//!    subsample sizes.
//! 2. **Capped vs. uncapped final merge** (Alg. 1 line 9's
//!    `cc(nn(G), k)`): what exact-k extraction costs relative to
//!    letting the final round overshoot.
//! 3. **Compression ratio sweep**: fast-clustering cost vs p/k,
//!    verifying the O(log(p/k)) round count empirically.
//!
//! ```bash
//! cargo bench --bench ablations
//! ```

use fastclust::bench_harness::{timeit, Table};
use fastclust::cluster::metrics::{percolation_stats, within_cluster_inertia};
use fastclust::cluster::{Clusterer, FastCluster};
use fastclust::graph::LatticeGraph;
use fastclust::volume::SyntheticCube;

fn main() {
    let ds = SyntheticCube::new([24, 24, 24], 6.0, 1.0).generate(100, 5);
    let graph = LatticeGraph::from_mask(ds.mask());
    let p = ds.p();
    let k = p / 10;
    println!("ablation workload: p={p} n={} k={k}", ds.n());

    // --- 1. feature subsampling
    let mut t1 = Table::new(
        "ablation 1 — clustering features subsampled to m images",
        &["m", "seconds", "rel. inertia", "max/mean size"],
    );
    let full_labels = FastCluster::default()
        .fit(ds.data(), &graph, k, 0)
        .unwrap();
    let base_inertia = within_cluster_inertia(ds.data(), &full_labels);
    for m in [100usize, 30, 10, 3, 1] {
        let fc = FastCluster {
            feature_subsample: (m < 100).then_some(m),
            ..Default::default()
        };
        let (b, labels) = timeit(&format!("m={m}"), 0, 3, || {
            fc.fit(ds.data(), &graph, k, 0).unwrap()
        });
        let inertia = within_cluster_inertia(ds.data(), &labels);
        let stats = percolation_stats(&labels);
        t1.row(vec![
            m.to_string(),
            format!("{:.4}", b.mean_s),
            format!("{:.3}", inertia / base_inertia),
            format!("{:.1}", stats.max_over_mean),
        ]);
    }
    t1.print();

    // --- 2. capped vs uncapped final merge: compare requesting exact
    // k against the nearest power-of-two count the uncapped recursion
    // would naturally land on (k' <= k), measuring the cost delta.
    let mut t2 = Table::new(
        "ablation 2 — exact-k capped merge vs natural (uncapped) count",
        &["mode", "k", "seconds"],
    );
    let (b_exact, l_exact) = timeit("exact", 0, 3, || {
        FastCluster::default().fit(ds.data(), &graph, k, 0).unwrap()
    });
    // natural: run with k=1 cap removed by requesting the count the
    // trace shows one round above k
    let (_, trace) = FastCluster::default()
        .fit_trace(ds.data(), &graph, k, 0)
        .unwrap();
    let natural_k = *trace
        .cluster_counts
        .iter()
        .rev()
        .find(|&&c| c > k)
        .unwrap_or(&k);
    let (b_nat, l_nat) = timeit("natural", 0, 3, || {
        FastCluster::default().fit(ds.data(), &graph, natural_k, 0).unwrap()
    });
    t2.row(vec![
        "capped (exact k)".into(),
        l_exact.k.to_string(),
        format!("{:.4}", b_exact.mean_s),
    ]);
    t2.row(vec![
        "uncapped round".into(),
        l_nat.k.to_string(),
        format!("{:.4}", b_nat.mean_s),
    ]);
    t2.print();

    // --- 3. ratio sweep: rounds grow logarithmically, cost ~linearly
    let mut t3 = Table::new(
        "ablation 3 — cost & rounds vs compression ratio p/k",
        &["p/k", "k", "rounds", "seconds"],
    );
    for ratio in [2usize, 5, 10, 20, 50] {
        let kk = (p / ratio).max(2);
        let (b, tr) = timeit(&format!("r={ratio}"), 0, 3, || {
            FastCluster::default()
                .fit_trace(ds.data(), &graph, kk, 0)
                .unwrap()
                .1
        });
        t3.row(vec![
            ratio.to_string(),
            kk.to_string(),
            (tr.cluster_counts.len() - 1).to_string(),
            format!("{:.4}", b.mean_s),
        ]);
    }
    t3.print();

    println!(
        "\nreading: m=10 subsampling ~matches full-feature quality at a \
         fraction of the cost (paper §5); exact-k extraction costs no \
         more than the uncapped recursion; rounds grow with log(p/k) \
         while cost stays ~flat (linear-time claim)."
    );
}
