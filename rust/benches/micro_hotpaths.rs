//! Micro-benchmarks of the library hot paths — the §Perf working set:
//!
//! * fast clustering end-to-end (the paper's algorithmic contribution);
//! * 1-NN graph extraction + capped CC (Alg. 1 inner loop);
//! * `ClusterReduce::reduce` (U^T X — the per-sample compression op);
//! * sparse-RP apply;
//! * native logreg gradient step;
//! * PJRT logreg step (AOT artifact), when artifacts are present.
//!
//! Prints voxels/s and GB/s so EXPERIMENTS.md §Perf can compare against
//! memory-bandwidth roofline.
//!
//! ```bash
//! cargo bench --bench micro_hotpaths
//! ```

use fastclust::bench_harness::timeit;
use fastclust::cluster::{Clusterer, FastCluster};
use fastclust::estimators::{LogisticRegression, LogregBackend};
use fastclust::graph::{nearest_neighbor_edges, LatticeGraph};
use fastclust::reduce::{ClusterReduce, Reducer, SparseRandomProjection};
use fastclust::runtime::Runtime;
use fastclust::volume::SyntheticCube;

fn main() {
    // a paper-regime volume: p = 27k voxels, n = 50 samples
    let dims = [30, 30, 30];
    let n = 50;
    let ds = SyntheticCube::new(dims, 6.0, 1.0).generate(n, 1);
    let p = ds.p();
    let k = p / 10;
    let graph = LatticeGraph::from_mask(ds.mask());
    println!("workload: p={p} n={n} k={k} edges={}", graph.n_edges());

    // --- fast clustering end-to-end
    let (b, labels) = timeit("fast_cluster_p27k", 1, 3, || {
        FastCluster::default().fit(ds.data(), &graph, k, 0).unwrap()
    });
    println!("{}  [{:.2} Mvoxel/s]", b.summary(), p as f64 / b.min_s / 1e6);

    // --- 1-NN extraction on the full lattice
    let weighted = {
        let mut g = graph.clone();
        for e in &mut g.edges {
            e.w = ds.data().row_sqdist(e.u as usize, e.v as usize);
        }
        g
    };
    let (b, _) = timeit("nn_edges_p27k", 1, 5, || {
        nearest_neighbor_edges(&weighted).len()
    });
    println!(
        "{}  [{:.2} Medge/s]",
        b.summary(),
        graph.n_edges() as f64 / b.min_s / 1e6
    );

    // --- cluster reduction U^T X
    let red = ClusterReduce::from_labels(&labels);
    let bytes = (p * n * 4) as f64;
    let (b, _) = timeit("cluster_reduce_p27k_n50", 1, 10, || {
        red.reduce(ds.data()).rows
    });
    println!(
        "{}  [{:.2} GB/s read]",
        b.summary(),
        bytes / b.min_s / 1e9
    );

    // --- sparse random projection apply
    let rp = SparseRandomProjection::new(p, k, 3);
    let (b, _) = timeit("sparse_rp_p27k_n50", 1, 5, || {
        rp.reduce(ds.data()).rows
    });
    println!(
        "{}  [{:.2} Mnnz/s]",
        b.summary(),
        (rp.nnz() * n) as f64 / b.min_s / 1e6
    );

    // --- logreg gradient step on compressed features (native)
    let xk = red.reduce(ds.data()).transpose(); // (n, k)
    let y: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
    let lr = LogisticRegression {
        max_iter: 1,
        tol: 0.0,
        ..Default::default()
    };
    let (b, _) = timeit("logreg_step_native", 1, 10, || {
        lr.fit(&xk, &y).unwrap().evals
    });
    println!(
        "{}  [{:.2} Melem/s]",
        b.summary(),
        (n * k) as f64 * 3.0 / b.min_s / 1e6
    );

    // --- PJRT artifact paths (when built): per-eval step vs fused GD.
    // The fused artifact amortizes the PJRT dispatch overhead over 64
    // GD steps per call — compare seconds *per gradient step*.
    match Runtime::from_env() {
        Ok(rt) => {
            let rt = std::sync::Arc::new(rt);
            let kk = 2048.min(k);
            let xs = xk.select_cols(&(0..kk).collect::<Vec<_>>());
            let lr_rt = LogisticRegression {
                max_iter: 1,
                tol: 0.0,
                backend: LogregBackend::Runtime(rt.clone()),
                ..Default::default()
            };
            let (b, _) = timeit("logreg_step_pjrt(1 step)", 1, 5, || {
                lr_rt.fit(&xs, &y).unwrap().evals
            });
            println!("{}", b.summary());
            let per_step_single = b.min_s / 2.0; // ~2 evals in 1 iter

            let lr_fused = LogisticRegression {
                max_iter: 64,
                tol: 0.0,
                ..Default::default()
            };
            let (b, fit) = timeit("logreg_gd64_pjrt(64 steps)", 1, 5, || {
                lr_fused.fit_fused(&rt, &xs, &y).unwrap()
            });
            println!("{}", b.summary());
            let per_step_fused = b.min_s / fit.iters.max(1) as f64;
            println!(
                "  per-step: single-dispatch {:.3} ms vs fused {:.3} ms \
                 -> {:.0}x dispatch amortization",
                per_step_single * 1e3,
                per_step_fused * 1e3,
                per_step_single / per_step_fused.max(1e-12)
            );
        }
        Err(_) => println!("(artifacts not built; skipping PJRT bench)"),
    }
}
