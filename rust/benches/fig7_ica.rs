//! Bench: regenerate Fig 7 (ICA component recovery, cross-session
//! consistency with Wilcoxon test, computation time gain).
//!
//! ```bash
//! cargo bench --bench fig7_ica
//! ```

use fastclust::bench_harness::{fig7, write_csv};

fn main() {
    let cfg = fig7::Fig7Config::default();
    println!(
        "Fig 7 driver: dims={:?} subjects={} t={} ratio={} q={}",
        cfg.dims, cfg.n_subjects, cfg.t, cfg.ratio, cfg.q
    );
    let res = fig7::run(&cfg);
    let table = fig7::table(&res);
    table.print();
    write_csv(&table, std::path::Path::new("results/fig7_ica.csv"))
        .expect("csv");

    let n = res.subjects.len() as f64;
    let fast_rec: f64 =
        res.subjects.iter().map(|s| s.fast_vs_raw).sum::<f64>() / n;
    let rp_rec: f64 =
        res.subjects.iter().map(|s| s.rp_vs_raw).sum::<f64>() / n;
    assert!(
        fast_rec > rp_rec,
        "REGRESSION: fast recovery {fast_rec} !> rp {rp_rec}"
    );
    assert!(
        res.gain_factor > 1.5,
        "REGRESSION: ICA speedup {}x too small",
        res.gain_factor
    );
    println!(
        "fig7 OK: recovery fast {:.2} vs rp {:.2}; gain {:.1}x; wilcoxon {}",
        fast_rec,
        rp_rec,
        res.gain_factor,
        res.wilcoxon_p
            .map(|p| format!("p={p:.2e}"))
            .unwrap_or_else(|| "n/a".into())
    );
}
