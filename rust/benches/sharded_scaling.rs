//! Bench: the sharded parallel engine vs single-thread Alg. 1 —
//! wall-clock scaling across shard counts plus the variance-ratio and
//! η quality metrics (docs/adr/002 acceptance numbers).
//!
//! ```bash
//! cargo bench --bench sharded_scaling
//! ```

use fastclust::bench_harness::{sharded, write_csv};

fn main() {
    let cfg = sharded::ShardedConfig::default();
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "sharded scaling driver: dims={:?} subjects={} contrasts={} \
         ratio={} shard_counts={:?} ({cores} cores)",
        cfg.dims, cfg.n_subjects, cfg.n_contrasts, cfg.ratio,
        cfg.shard_counts
    );
    let rows = sharded::run(&cfg);
    let table = sharded::table(&rows);
    table.print();
    write_csv(&table, std::path::Path::new("results/sharded_scaling.csv"))
        .expect("csv");

    // hard acceptance gates (ADR-002) — shared implementation
    sharded::check_gates(&rows).expect("acceptance gates");
    let best = rows
        .iter()
        .filter(|r| r.shards > 1)
        .map(|r| r.speedup)
        .fold(f64::NAN, f64::max);
    if cores >= 2 && rows.iter().any(|r| r.shards > 1) {
        assert!(
            best > 1.0,
            "REGRESSION: no multi-core speedup (best {best:.2}x)"
        );
        println!(
            "sharded scaling OK: best speedup {best:.2}x on {cores} cores"
        );
    } else {
        println!("single core available — speedup gate skipped");
    }
}
