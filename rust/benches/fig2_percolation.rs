//! Bench: regenerate Fig 2 (percolation histograms across methods)
//! at the default testbed scale and print the paper-style table.
//!
//! ```bash
//! cargo bench --bench fig2_percolation
//! ```

use fastclust::bench_harness::{fig2, timeit, write_csv};

fn main() {
    let cfg = fig2::Fig2Config::default();
    println!(
        "Fig 2 driver: dims={:?} subjects={} ratio={}",
        cfg.dims, cfg.n_subjects, cfg.ratio
    );
    let (bench, rows) = timeit("fig2_full", 0, 1, || fig2::run(&cfg));
    println!("{}", bench.summary());
    let table = fig2::table(&rows);
    table.print();
    write_csv(&table, std::path::Path::new("results/fig2_percolation.csv"))
        .expect("csv");
    // the paper's qualitative check, enforced in CI fashion
    let fast = rows
        .iter()
        .find(|r| r.method == fastclust::config::Method::Fast)
        .unwrap();
    let single = rows
        .iter()
        .find(|r| r.method == fastclust::config::Method::Single)
        .unwrap();
    assert!(
        fast.giant_fraction < single.giant_fraction,
        "REGRESSION: fast clustering percolates more than single linkage"
    );
    println!("fig2 OK: fast giant fraction {:.4} < single {:.4}",
        fast.giant_fraction, single.giant_fraction);
}
