//! Bench: the out-of-core streaming pipeline vs the in-memory
//! pipeline on the Fig-6 synthetic cohort — wall time, streaming
//! throughput, analytic peak matrix memory, and the ADR-003
//! acceptance gates (identical fold accuracies, bounded working set).
//!
//! ```bash
//! cargo bench --bench streaming_oocore
//! ```

use fastclust::bench_harness::{streaming, write_bench_report};

fn main() {
    let cfg = streaming::StreamingBenchConfig::default();
    println!(
        "streaming driver: dims={:?} subjects={} chunk={} ratio={} \
         folds={}",
        cfg.dims, cfg.n_subjects, cfg.chunk_samples, cfg.ratio,
        cfg.cv_folds
    );
    let r = streaming::run(&cfg).expect("streaming bench failed");
    streaming::table(&r).print();

    // hard acceptance gates (ADR-003) — shared implementation
    streaming::check_gates(&r).expect("acceptance gates");
    println!(
        "streaming OK: acc {:.4} (= in-memory), bounded peak matrix \
         {:.2} MB vs {:.2} MB dense, {:.1} MB/s",
        r.stream.accuracy,
        r.bounded.peak_matrix_bytes as f64 / (1024.0 * 1024.0),
        r.bounded.inmem_matrix_bytes as f64 / (1024.0 * 1024.0),
        r.throughput_mb_per_s
    );

    let path = std::path::Path::new("results/BENCH_streaming.json");
    write_bench_report(path, &streaming::report_json(&r)).expect("json");
    println!("[json] {}", path.display());
}
