# L1 correctness contract: every Pallas kernel == its pure-jnp oracle.
# hypothesis sweeps shapes (deliberately non-tile-multiples to exercise
# the padding paths) and dtypes; assert_allclose against ref.py.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

from compile.kernels import logreg as klogreg
from compile.kernels import matmul as kmatmul
from compile.kernels import ref
from compile.kernels import rowdist as krowdist

HSET = settings(max_examples=12, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


# ----------------------------------------------------------------- matmul
@HSET
@given(
    m=st.integers(1, 200),
    p=st.integers(1, 200),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, p, n, seed):
    r = _rng(seed)
    a = r.standard_normal((m, p), dtype=np.float32)
    b = r.standard_normal((p, n), dtype=np.float32)
    got = kmatmul.matmul(a, b, bm=32, bn=32, bp=32)
    want = ref.matmul(a, b)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32])
def test_matmul_dtypes(dtype):
    r = _rng(0)
    a = (r.standard_normal((17, 9)) * 3).astype(dtype)
    b = (r.standard_normal((9, 21)) * 3).astype(dtype)
    got = kmatmul.matmul(a, b, bm=16, bn=16, bp=16)
    want = ref.matmul(jnp.asarray(a), jnp.asarray(b))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_matmul_exact_tile_multiple():
    r = _rng(1)
    a = r.standard_normal((128, 128), dtype=np.float32)
    b = r.standard_normal((128, 128), dtype=np.float32)
    got = kmatmul.matmul(a, b)  # default 128-tiles: no padding branch
    assert_allclose(np.asarray(got), np.asarray(ref.matmul(a, b)),
                    rtol=1e-5, atol=1e-5)


def test_matmul_identity():
    a = np.eye(37, dtype=np.float32)
    b = _rng(2).standard_normal((37, 11), dtype=np.float32)
    got = kmatmul.matmul(a, b, bm=16, bn=16, bp=16)
    assert_allclose(np.asarray(got), b, rtol=1e-6, atol=1e-6)


# --------------------------------------------------------- segment_reduce
@HSET
@given(
    p=st.integers(2, 300),
    k=st.integers(1, 40),
    n=st.integers(1, 50),
    seed=st.integers(0, 2**31 - 1),
)
def test_segment_reduce_matches_ref(p, k, n, seed):
    r = _rng(seed)
    labels = r.integers(0, k, size=p)
    u = np.eye(k, dtype=np.float32)[labels]
    x = r.standard_normal((p, n), dtype=np.float32)
    got = kmatmul.segment_reduce(u, x, bm=16, bn=16, bp=16)
    want = ref.segment_reduce(u, x)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@HSET
@given(
    p=st.integers(2, 200),
    k=st.integers(1, 20),
    n=st.integers(1, 30),
    seed=st.integers(0, 2**31 - 1),
)
def test_cluster_means_matches_ref_and_numpy(p, k, n, seed):
    r = _rng(seed)
    labels = r.integers(0, k, size=p)
    u = np.eye(k, dtype=np.float32)[labels]
    x = r.standard_normal((p, n), dtype=np.float32)
    got = np.asarray(kmatmul.cluster_means(u, x, bm=16, bn=16, bp=16))
    want = np.asarray(ref.cluster_means(u, x))
    assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # independent numpy ground truth (empty clusters -> 0 rows)
    for c in range(k):
        m = labels == c
        exp = x[m].mean(axis=0) if m.any() else np.zeros(n, np.float32)
        assert_allclose(got[c], exp, rtol=1e-4, atol=1e-5)


def test_cluster_means_constant_preserved():
    # reduction of a constant image is constant — the paper's projector
    # property <x, u_i/||u_i||^2> for x = c*1.
    p, k, n = 101, 7, 5
    labels = _rng(3).integers(0, k, size=p)
    # ensure every cluster non-empty
    labels[:k] = np.arange(k)
    u = np.eye(k, dtype=np.float32)[labels]
    x = np.full((p, n), 3.25, dtype=np.float32)
    got = np.asarray(kmatmul.cluster_means(u, x, bm=16, bn=16, bp=16))
    assert_allclose(got, np.full((k, n), 3.25), rtol=1e-6)


# ---------------------------------------------------------------- rowdist
@HSET
@given(
    e=st.integers(1, 400),
    n=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_rowwise_sqdist_matches_ref(e, n, seed):
    r = _rng(seed)
    a = r.standard_normal((e, n), dtype=np.float32)
    b = r.standard_normal((e, n), dtype=np.float32)
    got = krowdist.rowwise_sqdist(a, b, be=32, bn=32)
    want = ref.rowwise_sqdist(a, b)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_rowwise_sqdist_zero_and_symmetry():
    r = _rng(4)
    a = r.standard_normal((33, 17), dtype=np.float32)
    assert_allclose(np.asarray(krowdist.rowwise_sqdist(a, a, be=16, bn=16)),
                    np.zeros(33), atol=1e-6)
    b = r.standard_normal((33, 17), dtype=np.float32)
    dab = np.asarray(krowdist.rowwise_sqdist(a, b, be=16, bn=16))
    dba = np.asarray(krowdist.rowwise_sqdist(b, a, be=16, bn=16))
    assert_allclose(dab, dba, rtol=1e-6)
    assert (dab >= 0).all()


# ----------------------------------------------------------------- logreg
@HSET
@given(
    n=st.integers(1, 300),
    k=st.integers(1, 120),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_matches_ref(n, k, seed):
    r = _rng(seed)
    x = r.standard_normal((n, k), dtype=np.float32)
    w = r.standard_normal(k, dtype=np.float32)
    got = klogreg.matvec(x, w, bn=32, bk=32)
    assert_allclose(np.asarray(got), np.asarray(ref.matvec(x, w)),
                    rtol=1e-4, atol=1e-4)


@HSET
@given(
    n=st.integers(1, 300),
    k=st.integers(1, 120),
    seed=st.integers(0, 2**31 - 1),
)
def test_tmatvec_matches_ref(n, k, seed):
    r = _rng(seed)
    x = r.standard_normal((n, k), dtype=np.float32)
    v = r.standard_normal(n, dtype=np.float32)
    got = klogreg.tmatvec(x, v, bn=32, bk=32)
    assert_allclose(np.asarray(got), np.asarray(ref.tmatvec(x, v)),
                    rtol=1e-4, atol=1e-4)


def test_matvec_tmatvec_adjoint():
    # <Xw, r> == <w, X^T r> — adjointness of the two kernels.
    r = _rng(5)
    x = r.standard_normal((57, 23), dtype=np.float32)
    w = r.standard_normal(23, dtype=np.float32)
    v = r.standard_normal(57, dtype=np.float32)
    lhs = float(np.dot(np.asarray(klogreg.matvec(x, w, bn=16, bk=16)), v))
    rhs = float(np.dot(w, np.asarray(klogreg.tmatvec(x, v, bn=16, bk=16))))
    assert abs(lhs - rhs) < 1e-2 * max(1.0, abs(lhs))


# ------------------------------------------------------- pairwise_sqdist
def test_pairwise_sqdist_ref_properties():
    r = _rng(6)
    s = r.standard_normal((19, 33), dtype=np.float32)
    d = np.asarray(ref.pairwise_sqdist(s))
    assert d.shape == (19, 19)
    assert_allclose(np.diag(d), np.zeros(19), atol=1e-4)
    assert_allclose(d, d.T, rtol=1e-5, atol=1e-4)
    brute = ((s[:, None, :] - s[None, :, :]) ** 2).sum(-1)
    assert_allclose(d, brute, rtol=1e-3, atol=1e-3)


# ------------------------------------------------------ logreg_loss_grad
def test_logreg_grad_matches_finite_differences():
    r = _rng(7)
    n, k = 40, 9
    x = r.standard_normal((n, k)).astype(np.float32)
    y = (r.random(n) > 0.5).astype(np.float32)
    sw = np.ones(n, dtype=np.float32)
    w = 0.1 * r.standard_normal(k).astype(np.float32)
    b, lam = np.float32(0.05), np.float32(0.3)
    loss, gw, gb = ref.logreg_loss_grad(x, y, sw, w, b, lam)
    eps = 1e-3
    for i in range(k):
        wp, wm = w.copy(), w.copy()
        wp[i] += eps
        wm[i] -= eps
        lp = ref.logreg_loss_grad(x, y, sw, wp, b, lam)[0]
        lm = ref.logreg_loss_grad(x, y, sw, wm, b, lam)[0]
        fd = (float(lp) - float(lm)) / (2 * eps)
        assert abs(fd - float(gw[i])) < 5e-3, (i, fd, float(gw[i]))
    lp = ref.logreg_loss_grad(x, y, sw, w, b + eps, lam)[0]
    lm = ref.logreg_loss_grad(x, y, sw, w, b - eps, lam)[0]
    assert abs((float(lp) - float(lm)) / (2 * eps) - float(gb)) < 5e-3


def test_logreg_padding_rows_are_exact():
    # sw=0 rows must not change loss or grad — the padding contract the
    # rust runtime relies on for fixed-shape artifacts.
    r = _rng(8)
    n, k, pad = 30, 7, 12
    x = r.standard_normal((n, k)).astype(np.float32)
    y = (r.random(n) > 0.5).astype(np.float32)
    w = 0.1 * r.standard_normal(k).astype(np.float32)
    sw = np.ones(n, dtype=np.float32)
    base = ref.logreg_loss_grad(x, y, sw, w, 0.0, 0.1)

    xp = np.vstack([x, r.standard_normal((pad, k)).astype(np.float32)])
    yp = np.concatenate([y, np.ones(pad, np.float32)])
    swp = np.concatenate([sw, np.zeros(pad, np.float32)])
    padded = ref.logreg_loss_grad(xp, yp, swp, w, 0.0, 0.1)

    assert_allclose(float(base[0]), float(padded[0]), rtol=1e-6)
    assert_allclose(np.asarray(base[1]), np.asarray(padded[1]), rtol=1e-5,
                    atol=1e-6)
    assert_allclose(float(base[2]), float(padded[2]), rtol=1e-5, atol=1e-7)


def test_logreg_grad_is_jax_grad():
    # oracle gradient == autodiff gradient of the oracle loss
    r = _rng(9)
    n, k = 25, 6
    x = jnp.asarray(r.standard_normal((n, k)), dtype=jnp.float32)
    y = jnp.asarray((r.random(n) > 0.5), dtype=jnp.float32)
    sw = jnp.ones(n, dtype=jnp.float32)
    w = jnp.asarray(0.2 * r.standard_normal(k), dtype=jnp.float32)

    def loss_fn(wb):
        return ref.logreg_loss_grad(x, y, sw, wb[:k], wb[k], 0.2)[0]

    wb = jnp.concatenate([w, jnp.zeros(1)])
    g = jax.grad(loss_fn)(wb)
    _, gw, gb = ref.logreg_loss_grad(x, y, sw, w, 0.0, 0.2)
    assert_allclose(np.asarray(g[:k]), np.asarray(gw), rtol=1e-4, atol=1e-5)
    assert_allclose(float(g[k]), float(gb), rtol=1e-4, atol=1e-5)
