# L2 model graphs: pallas-backed programs == oracle programs, and the
# artifact table is well-formed (shapes eval, names stable).
import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model


def _rng(seed=0):
    return np.random.default_rng(seed)


def test_reduce_apply_kernel_vs_ref():
    r = _rng(10)
    p, k, n = 257, 19, 23
    labels = r.integers(0, k, size=p)
    labels[:k] = np.arange(k)
    u = np.eye(k, dtype=np.float32)[labels]
    x = r.standard_normal((p, n), dtype=np.float32)
    got = model.reduce_apply(u, x)
    want = model.reduce_apply_ref(u, x)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_edge_sqdist_kernel_vs_ref():
    r = _rng(11)
    p, n, e = 150, 31, 400
    x = r.standard_normal((p, n), dtype=np.float32)
    src = r.integers(0, p, size=e).astype(np.int32)
    dst = r.integers(0, p, size=e).astype(np.int32)
    got = model.edge_sqdist(x, src, dst)
    want = model.edge_sqdist_ref(x, src, dst)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_logreg_step_kernel_vs_ref():
    r = _rng(12)
    n, k = 65, 33
    x = r.standard_normal((n, k), dtype=np.float32)
    y = (r.random(n) > 0.4).astype(np.float32)
    sw = np.ones(n, dtype=np.float32)
    w = 0.1 * r.standard_normal(k).astype(np.float32)
    got = model.logreg_step(x, y, sw, w, jnp.float32(0.1), jnp.float32(0.5))
    want = model.logreg_step_ref(x, y, sw, w, jnp.float32(0.1),
                                 jnp.float32(0.5))
    for g, wv in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(wv), rtol=1e-4, atol=1e-5)


def test_pairwise_sqdist_kernel_vs_ref():
    r = _rng(13)
    s = r.standard_normal((21, 65), dtype=np.float32)
    got = model.pairwise_sqdist(s)
    want = model.pairwise_sqdist_ref(s)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


def test_artifact_table_shapes_eval():
    # every artifact function abstract-evals on its declared shapes
    table = model.artifact_table()
    assert len(table) >= 6
    for name, (fn, args) in table.items():
        out = jax.eval_shape(fn, *args)
        leaves = jax.tree_util.tree_leaves(out)
        assert leaves, name
        for leaf in leaves:
            assert all(d >= 0 for d in leaf.shape), name


def test_artifact_table_names_stable():
    # the rust runtime keys on these exact names — breaking them is an
    # API break, caught here.
    names = set(model.artifact_table())
    expected = {
        "reduce_apply_p4096_k512_n64",
        "reduce_apply_p8192_k1024_n128",
        "logreg_step_n512_k512",
        "logreg_step_n512_k2048",
        "pairwise_sqdist_n256_d2048",
        "edge_sqdist_e16384_n64",
        "smoke_matmul_2x2",
    }
    assert expected <= names


def test_reduce_apply_handles_padded_rows():
    # zero rows of U (padding the masked-voxel count up to the artifact
    # shape) must not perturb cluster means.
    r = _rng(14)
    p, k, n, pad = 120, 9, 8, 40
    labels = r.integers(0, k, size=p)
    labels[:k] = np.arange(k)
    u = np.eye(k, dtype=np.float32)[labels]
    x = r.standard_normal((p, n), dtype=np.float32)
    base = np.asarray(model.reduce_apply_ref(u, x))

    up = np.vstack([u, np.zeros((pad, k), np.float32)])
    xp = np.vstack([x, r.standard_normal((pad, n), dtype=np.float32)])
    padded = np.asarray(model.reduce_apply_ref(up, xp))
    assert_allclose(base, padded, rtol=1e-5, atol=1e-6)
