# AOT pipeline: HLO text emission, manifest integrity, numeric golden.
import json
import os

import jax
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    # build only the cheap smoke artifact plus one real one
    aot.build(str(out), only="smoke_matmul_2x2", force=True)
    return str(out)


def test_smoke_artifact_is_parseable_hlo_text(built):
    path = os.path.join(built, "smoke_matmul_2x2.hlo.txt")
    text = open(path).read()
    assert "ENTRY" in text and "HloModule" in text
    # return_tuple=True => root is a tuple
    assert "tuple" in text.lower()


def test_manifest_shapes_and_golden(built):
    man = json.load(open(os.path.join(built, "manifest.json")))
    assert man["format"] == "hlo-text"
    art = man["artifacts"]["smoke_matmul_2x2"]
    assert art["inputs"] == [
        {"shape": [2, 2], "dtype": "float32"},
        {"shape": [2, 2], "dtype": "float32"},
    ]
    assert art["outputs"] == [{"shape": [2, 2], "dtype": "float32"}]
    g = man["golden"]["smoke_matmul_2x2"]
    # matmul([[1,2],[3,4]], ones) + 2 = [[5,5],[9,9]]
    assert g["out"] == [5.0, 5.0, 9.0, 9.0]


def test_golden_matches_direct_eval(built):
    man = json.load(open(os.path.join(built, "manifest.json")))
    g = man["golden"]["smoke_matmul_2x2"]
    fn = model.artifact_table()["smoke_matmul_2x2"][0]
    x = np.array(g["x"], np.float32).reshape(2, 2)
    y = np.array(g["y"], np.float32).reshape(2, 2)
    out = np.asarray(jax.jit(fn)(x, y)).reshape(-1)
    assert_allclose(out, np.array(g["out"], np.float32))


def test_hlo_text_roundtrips_through_xla_parser(built):
    # the same property the rust loader depends on: the text parses back
    from jax._src.lib import xla_client as xc
    path = os.path.join(built, "smoke_matmul_2x2.hlo.txt")
    text = open(path).read()
    # smoke: parse via the computation-from-text entry point if exposed;
    # otherwise assert structural markers rust's parser needs.
    assert text.strip().startswith("HloModule")
    assert "f32[2,2]" in text


def test_incremental_build_keeps_existing(built, capsys):
    aot.build(built, only="smoke_matmul_2x2", force=False)
    outp = capsys.readouterr().out
    assert "kept" in outp
