import os
import sys

# Run everything on the CPU PJRT client, like the rust runtime does.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# `cd python && pytest tests/` — make the `compile` package importable
# whether pytest is invoked from python/ or the repo root.
_here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _here not in sys.path:
    sys.path.insert(0, _here)
