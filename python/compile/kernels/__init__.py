# L1: Pallas kernels for the paper's compute hot-spots.
#
#   matmul.py  — tiled MXU matmul; cluster reduction U^T X rides on it
#   rowdist.py — blocked edge-distance kernel for Alg. 1's graph weights
#   logreg.py  — matvec / tmatvec pair for the logistic gradient step
#   ref.py     — pure-jnp oracles (the correctness contract)
from . import logreg, matmul, ref, rowdist  # noqa: F401
