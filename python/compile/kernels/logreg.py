# L1 Pallas kernels for the logistic-regression hot loop.
#
# The downstream estimator the paper accelerates is an L2-logistic
# regression on compressed features X_k (n, k). One gradient step is
# two matrix-vector products around a cheap nonlinearity:
#
#     z = X_k w          (matvec,   MXU tile-parallel over n)
#     r = sw * (sigmoid(z) - y)     (VPU, done in L2 jnp)
#     g = X_k^T r / m + lam * w     (tmatvec, MXU tile-parallel over k)
#
# Both products are blocked Pallas kernels; zero padding is exact for
# both. Vectors are carried as (len, 1) 2-D blocks — TPU Pallas wants
# >=2-D tiles and the lane dimension last.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 256  # sample-tile
DEFAULT_BK = 256  # feature-tile


def _matvec_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def matvec(x, w, *, bn=DEFAULT_BN, bk=DEFAULT_BK, interpret=True):
    """z = X @ w. x: (n, k), w: (k,) -> (n,) f32."""
    n, k = x.shape
    pn, pk = (-n) % bn, (-k) % bk
    x = jnp.pad(x.astype(jnp.float32), ((0, pn), (0, pk)))
    wc = jnp.pad(w.astype(jnp.float32), (0, pk))[:, None]  # (kp, 1)
    npad, kpad = x.shape
    out = pl.pallas_call(
        _matvec_kernel,
        grid=(npad // bn, kpad // bk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bk, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, 1), jnp.float32),
        interpret=interpret,
    )(x, wc)
    return out[:n, 0]


def _tmatvec_kernel(x_ref, r_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].T, r_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def tmatvec(x, r, *, bn=DEFAULT_BN, bk=DEFAULT_BK, interpret=True):
    """g = X^T r. x: (n, k), r: (n,) -> (k,) f32."""
    n, k = x.shape
    pn, pk = (-n) % bn, (-k) % bk
    x = jnp.pad(x.astype(jnp.float32), ((0, pn), (0, pk)))
    rc = jnp.pad(r.astype(jnp.float32), (0, pn))[:, None]  # (np, 1)
    npad, kpad = x.shape
    out = pl.pallas_call(
        _tmatvec_kernel,
        grid=(kpad // bk, npad // bn),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j: (j, i)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bk, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((kpad, 1), jnp.float32),
        interpret=interpret,
    )(x, rc)
    return out[:k, 0]
