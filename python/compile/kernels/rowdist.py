# L1 Pallas kernel: blocked row-wise squared distances.
#
# Fast clustering (Alg. 1) weights each lattice edge (i, j) with
# ||x_i - x_j||^2. The L2 graph gathers the edge endpoint rows into two
# dense (e, n) matrices (gather is XLA's job; the kernel stays
# gather-free) and this kernel reduces each row pair — a pure VPU
# (vector unit) workload: elementwise subtract, square, row-sum.
#
# Tiling: (be, bn) blocks; grid dim 1 accumulates partial row sums over
# feature tiles into the (be,) output block (revisiting semantics).
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BE = 256
DEFAULT_BN = 128


def _rowdist_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = a_ref[...] - b_ref[...]
    o_ref[...] += jnp.sum(d * d, axis=1)


@functools.partial(jax.jit, static_argnames=("be", "bn", "interpret"))
def rowwise_sqdist(a, b, *, be=DEFAULT_BE, bn=DEFAULT_BN, interpret=True):
    """d_e = ||a_e - b_e||^2. a, b: (e, n) -> (e,) f32.

    Zero padding is exact (padded rows contribute 0 and are sliced off).
    """
    assert a.shape == b.shape, (a.shape, b.shape)
    e, n = a.shape
    pe, pn = (-e) % be, (-n) % bn
    a = jnp.pad(a.astype(jnp.float32), ((0, pe), (0, pn)))
    b = jnp.pad(b.astype(jnp.float32), ((0, pe), (0, pn)))
    ep, np_ = a.shape
    out = pl.pallas_call(
        _rowdist_kernel,
        grid=(ep // be, np_ // bn),
        in_specs=[
            pl.BlockSpec((be, bn), lambda i, j: (i, j)),
            pl.BlockSpec((be, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((be,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((ep,), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out[:e]
