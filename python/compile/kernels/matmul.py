# L1 Pallas kernel: blocked matmul — the compression hot-spot.
#
# The paper's compression operator is the cluster reduction U^T X
# ((U^T U)^{-1} U^T X once divided by counts). On TPU the idiomatic
# mapping is a tiled one-hot matmul on the MXU: the one-hot U is fed
# in (bm, bp) VMEM tiles, X in (bp, bn) tiles, and a grid dimension
# iterates over p accumulating into the (bm, bn) output tile. BlockSpec
# expresses the HBM->VMEM schedule; the accumulator lives in the output
# block across the innermost grid dimension (revisiting semantics).
#
# interpret=True on this testbed (CPU PJRT cannot run Mosaic); the
# tiling structure — not interpret-mode wallclock — is what carries to
# real TPUs. See DESIGN.md §Hardware-Adaptation.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: MXU-native 128 lanes; (128 x 128) f32 tiles are
# 64 KiB each, so a (acc + a + b) working set is ~192 KiB — far inside
# a 16 MiB VMEM budget, leaving room for double buffering.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BP = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (bm, bn) output tile; grid dim 2 walks the p tiles."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x, mults):
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if all(p[1] == 0 for p in pads):
        return x
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bp", "interpret"))
def matmul(a, b, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bp=DEFAULT_BP,
           interpret=True):
    """C = A @ B with Pallas tiling. A: (m, p), B: (p, n) -> (m, n) f32.

    Arbitrary shapes are zero-padded up to tile multiples and the
    result is sliced back — zero padding is exact for matmul.
    """
    m, p = a.shape
    p2, n = b.shape
    assert p == p2, f"inner dims differ: {p} vs {p2}"
    a = _pad_to(a.astype(jnp.float32), (bm, bp))
    b = _pad_to(b.astype(jnp.float32), (bp, bn))
    mp, pp = a.shape
    _, np_ = b.shape
    grid = (mp // bm, np_ // bn, pp // bp)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bp), lambda i, j, k: (i, k)),
            pl.BlockSpec((bp, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]


def segment_reduce(onehot_u, x, **kw):
    """Cluster-sum S = U^T X via the tiled matmul. U: (p, k), X: (p, n)."""
    return matmul(onehot_u.T, x, **kw)


def cluster_means(onehot_u, x, **kw):
    """(U^T U)^{-1} U^T X — the paper's compressed representation."""
    sums = segment_reduce(onehot_u, x, **kw)
    counts = jnp.sum(onehot_u, axis=0)
    return sums / jnp.maximum(counts, 1.0)[:, None]
