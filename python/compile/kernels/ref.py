# Pure-jnp correctness oracles for the Pallas kernels (L1).
#
# Every kernel in this package has an oracle here with the *same*
# signature; pytest sweeps shapes/dtypes with hypothesis and asserts
# allclose between kernel and oracle. These oracles are also the L2
# fallback path used when a shape has no AOT artifact.
import jax.numpy as jnp


def matmul(a, b):
    """C = A @ B. A: (m, p), B: (p, n) -> (m, n), f32 accumulation."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))


def segment_reduce(onehot_u, x):
    """Cluster-sum reduction S = U^T X.

    onehot_u: (p, k) one-hot assignment matrix (float), x: (p, n).
    Returns (k, n) per-cluster feature sums (NOT means; the caller
    divides by counts so that zero-padded rows stay exact).
    """
    return jnp.dot(onehot_u.T.astype(jnp.float32), x.astype(jnp.float32))


def cluster_means(onehot_u, x):
    """Cluster means (U^T U)^{-1} U^T X with empty-cluster guard."""
    sums = segment_reduce(onehot_u, x)
    counts = jnp.sum(onehot_u, axis=0)
    return sums / jnp.maximum(counts, 1.0)[:, None]


def rowwise_sqdist(a, b):
    """d_e = ||a_e - b_e||^2 row by row. a, b: (e, n) -> (e,)."""
    d = a.astype(jnp.float32) - b.astype(jnp.float32)
    return jnp.sum(d * d, axis=1)


def matvec(x, w):
    """z = X @ w. x: (n, k), w: (k,) -> (n,)."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))


def tmatvec(x, r):
    """g = X^T r. x: (n, k), r: (n,) -> (k,)."""
    return jnp.dot(x.T.astype(jnp.float32), r.astype(jnp.float32))


def pairwise_sqdist(s):
    """Full pairwise squared distances of row-samples. s: (n, d) -> (n, n)."""
    s = s.astype(jnp.float32)
    sq = jnp.sum(s * s, axis=1)
    d = sq[:, None] + sq[None, :] - 2.0 * jnp.dot(s, s.T)
    return jnp.maximum(d, 0.0)


def sigmoid(z):
    return 0.5 * (jnp.tanh(0.5 * z) + 1.0)


def logreg_loss_grad(x, y, sw, w, b, lam):
    """Weighted L2-regularized logistic loss + gradient.

    x: (n, k) compressed features, y: (n,) in {0,1}, sw: (n,) sample
    weights (0 for padding rows), w: (k,), b: scalar, lam: scalar.
    Returns (loss, gw, gb). Intercept b is NOT regularized (sklearn
    convention, which the paper relies on).
    """
    x = x.astype(jnp.float32)
    z = jnp.dot(x, w) + b
    # logaddexp(0, z) - y*z is the numerically stable Bernoulli NLL.
    nll = jnp.logaddexp(0.0, z) - y * z
    m = jnp.maximum(jnp.sum(sw), 1.0)
    loss = jnp.sum(sw * nll) / m + 0.5 * lam * jnp.dot(w, w)
    r = sw * (sigmoid(z) - y)
    gw = jnp.dot(x.T, r) / m + lam * w
    gb = jnp.sum(r) / m
    return loss, gw, gb
