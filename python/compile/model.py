# L2: the JAX compute graphs that get AOT-lowered to HLO text.
#
# Each public function here is a fixed-shape jax program calling the L1
# Pallas kernels; python/compile/aot.py lowers them once per shape entry
# in artifact_table() and the rust runtime (rust/src/runtime/) loads +
# executes the artifacts. Python is never on the request path.
import jax
import jax.numpy as jnp

from .kernels import logreg as klogreg
from .kernels import matmul as kmatmul
from .kernels import ref
from .kernels import rowdist as krowdist


# --------------------------------------------------------------------
# reduce_apply: the paper's compression operator.
# Inputs: onehot U (p, k) and data X (p, n). Output: cluster means
# (k, n) == (U^T U)^{-1} U^T X.  Zero-padded rows of U are exact.
# --------------------------------------------------------------------
def reduce_apply(onehot_u, x):
    return kmatmul.cluster_means(onehot_u, x)


def reduce_apply_ref(onehot_u, x):
    return ref.cluster_means(onehot_u, x)


# --------------------------------------------------------------------
# edge_sqdist: Alg. 1 graph weights. Inputs: X (p, n) row-major voxel
# features, src/dst (e,) int32 edge endpoints. Gather in XLA, reduce in
# the Pallas kernel.
# --------------------------------------------------------------------
def edge_sqdist(x, src, dst):
    a = jnp.take(x, src, axis=0)
    b = jnp.take(x, dst, axis=0)
    return krowdist.rowwise_sqdist(a, b)


def edge_sqdist_ref(x, src, dst):
    a = jnp.take(x, src, axis=0)
    b = jnp.take(x, dst, axis=0)
    return ref.rowwise_sqdist(a, b)


# --------------------------------------------------------------------
# logreg_step: one full-batch loss+gradient evaluation of the weighted
# L2-logistic objective on compressed features. The rust optimizer
# (GD + Armijo line search) drives this step.
# --------------------------------------------------------------------
def logreg_step(x, y, sw, w, b, lam):
    z = klogreg.matvec(x, w) + b
    nll = jnp.logaddexp(0.0, z) - y * z
    m = jnp.maximum(jnp.sum(sw), 1.0)
    loss = jnp.sum(sw * nll) / m + 0.5 * lam * jnp.dot(w, w)
    r = sw * (ref.sigmoid(z) - y)
    gw = klogreg.tmatvec(x, r) / m + lam * w
    gb = jnp.sum(r) / m
    return loss, gw, gb


def logreg_step_ref(x, y, sw, w, b, lam):
    return ref.logreg_loss_grad(x, y, sw, w, b, lam)


# --------------------------------------------------------------------
# logreg_gd: a FUSED multi-step gradient-descent artifact. The
# per-call PJRT dispatch overhead dominates single loss/grad artifacts
# (§Perf), so this program runs STEPS plain-GD iterations inside one
# XLA executable via lax.fori_loop and returns the final state plus the
# loss/gradient evaluated at it. The rust optimizer calls it in chunks,
# adapting the learning rate between chunks (backtracking at chunk
# granularity).
# --------------------------------------------------------------------
GD_STEPS = 64


def logreg_gd(x, y, sw, w0, b0, lam, lr):
    m = jnp.maximum(jnp.sum(sw), 1.0)

    def grad(w, b):
        z = jnp.dot(x, w) + b
        r = sw * (ref.sigmoid(z) - y)
        gw = jnp.dot(x.T, r) / m + lam * w
        gb = jnp.sum(r) / m
        return gw, gb

    def body(_, carry):
        w, b = carry
        gw, gb = grad(w, b)
        return (w - lr * gw, b - lr * gb)

    w, b = jax.lax.fori_loop(0, GD_STEPS, body, (w0, b0))
    z = jnp.dot(x, w) + b
    nll = jnp.logaddexp(0.0, z) - y * z
    loss = jnp.sum(sw * nll) / m + 0.5 * lam * jnp.dot(w, w)
    gw, gb = grad(w, b)
    return loss, w, b, gw, gb


# --------------------------------------------------------------------
# pairwise_sqdist: the eta-statistic workload of Fig 4 — all pairwise
# squared distances between row-samples, via the Gram matmul kernel.
# --------------------------------------------------------------------
def pairwise_sqdist(s):
    sq = jnp.sum(s * s, axis=1)
    gram = kmatmul.matmul(s, s.T)
    d = sq[:, None] + sq[None, :] - 2.0 * gram
    return jnp.maximum(d, 0.0)


def pairwise_sqdist_ref(s):
    return ref.pairwise_sqdist(s)


# --------------------------------------------------------------------
# AOT shape table: every (program, shape) pair that becomes an
# artifacts/*.hlo.txt. Names are stable API for the rust registry.
# Shapes are testbed-scale (see DESIGN.md §Scaling note). Artifacts
# lower the *_ref oracle graphs: interpret=True pallas inserts python
# callbacks into the HLO that only the python runtime can execute, so
# the AOT path ships the oracle while kernel≡oracle is enforced by
# pytest (python/tests/test_kernels.py).
# --------------------------------------------------------------------
def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_table():
    """name -> (fn, example_args). Single source of truth for aot.py."""
    table = {}

    for p, k, n in [(4096, 512, 64), (8192, 1024, 128)]:
        table[f"reduce_apply_p{p}_k{k}_n{n}"] = (
            reduce_apply_ref,
            (_spec((p, k)), _spec((p, n))),
        )

    for n, k in [(512, 512), (512, 2048)]:
        table[f"logreg_step_n{n}_k{k}"] = (
            logreg_step_ref,
            (
                _spec((n, k)),
                _spec((n,)),
                _spec((n,)),
                _spec((k,)),
                _spec((), jnp.float32),
                _spec((), jnp.float32),
            ),
        )

    for n, k in [(512, 512), (512, 2048)]:
        table[f"logreg_gd64_n{n}_k{k}"] = (
            logreg_gd,
            (
                _spec((n, k)),
                _spec((n,)),
                _spec((n,)),
                _spec((k,)),
                _spec((), jnp.float32),
                _spec((), jnp.float32),
                _spec((), jnp.float32),
            ),
        )

    for n, d in [(256, 2048)]:
        table[f"pairwise_sqdist_n{n}_d{d}"] = (
            pairwise_sqdist_ref,
            (_spec((n, d)),),
        )

    for e, n in [(16384, 64)]:
        table[f"edge_sqdist_e{e}_n{n}"] = (
            edge_sqdist_ref,
            (_spec((e, n)), _spec((e,), jnp.int32), _spec((e,), jnp.int32)),
        )

    # tiny smoke artifact for runtime integration tests (golden values
    # asserted on the rust side)
    def smoke(x, y):
        return jnp.dot(x, y) + 2.0

    table["smoke_matmul_2x2"] = (smoke, (_spec((2, 2)), _spec((2, 2))))
    return table
