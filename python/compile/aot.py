# AOT lowering: jax (L2) -> HLO text artifacts for the rust runtime.
#
# Interchange format is HLO *text*, not serialized HloModuleProto:
# jax >= 0.5 emits protos with 64-bit instruction ids which the xla
# crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
# text parser reassigns ids and round-trips cleanly. Lowered with
# return_tuple=True; the rust side unwraps the tuple.
#
# Also writes artifacts/manifest.json — the shape/dtype registry the
# rust runtime (rust/src/runtime/artifacts.rs) keys on — and golden
# probe values for the smoke artifact so rust integration tests can
# assert exact numerics.
import argparse
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .model import artifact_table


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(d):
    return np.dtype(d).name


def _flat_specs(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return [
        {"shape": list(leaf.shape), "dtype": _dtype_name(leaf.dtype)}
        for leaf in leaves
    ]


def build(outdir: str, only: str | None = None, force: bool = False):
    os.makedirs(outdir, exist_ok=True)
    manifest_path = os.path.join(outdir, "manifest.json")
    manifest = {"format": "hlo-text", "artifacts": {}}

    table = artifact_table()
    for name, (fn, example_args) in table.items():
        if only and only != name:
            continue
        path = os.path.join(outdir, f"{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*example_args)
        out_specs = _flat_specs(
            jax.eval_shape(fn, *example_args)
        )
        if force or not os.path.exists(path):
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            print(f"[aot] wrote {path} ({len(text)} chars)")
        else:
            print(f"[aot] kept  {path}")
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": _flat_specs(example_args),
            "outputs": out_specs,
        }

    # Golden probe for the smoke artifact: rust asserts these numbers.
    x = np.arange(1, 5, dtype=np.float32).reshape(2, 2)
    y = np.ones((2, 2), dtype=np.float32)
    fn = table["smoke_matmul_2x2"][0]
    golden = np.asarray(jax.jit(fn)(x, y)).reshape(-1).tolist()
    manifest["golden"] = {
        "smoke_matmul_2x2": {
            "x": x.reshape(-1).tolist(),
            "y": y.reshape(-1).tolist(),
            "out": golden,
        }
    }

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {manifest_path} ({len(manifest['artifacts'])} artifacts)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output dir (default: ../artifacts, for `cd python`)")
    ap.add_argument("--only", default=None, help="build a single artifact")
    ap.add_argument("--force", action="store_true",
                    help="rewrite even if the .hlo.txt exists")
    args = ap.parse_args()
    build(args.out, only=args.only, force=args.force)


if __name__ == "__main__":
    main()
